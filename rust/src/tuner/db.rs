//! Selection database: persisted (device, problem) -> winning point.
//!
//! This is the tuning artifact a deployment ships — the paper's "choosing
//! the combinations of kernel parameters that perform best on the
//! hardware", made durable.  JSON on disk (via [`crate::util::json`]);
//! the request path only does map lookups.
//!
//! Storage is **generic over [`KernelSpace`]**: [`SelectionDb::put`] /
//! [`SelectionDb::get`] work for any space, keyed by the space's `KIND`
//! string (`gemm_point`, `conv_point`, and the modeled zoo's `gemm` /
//! `conv`).  Legacy kinds (`blocked`, `conv_native`) still load and
//! resolve through each space's migration shim
//! ([`KernelSpace::from_legacy_json`]), and round-trip byte-identically
//! through save/load — migration to the unified schema happens only on
//! lookup, or explicitly via [`SelectionDb::merge`].  Loading rejects
//! corrupt entries, unknown kinds, and duplicate keys whose occurrences
//! carry conflicting kinds (previously a silent last-write-wins).
//!
//! Entries additionally carry *search provenance* — which
//! [`SearchStrategy`](crate::tuner::SearchStrategy) picked the winner
//! and how many points it measured ([`SelectionDb::annotate_search`]) —
//! so reports can show the measured-point savings of guided tuning.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::{ConvConfig, ConvPoint, GemmConfig, GemmPoint, KernelSpace};
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Problem-class key.  GEMM problems are bucketed by size class so nearby
/// shapes share a selection (the paper's Fig. 5 regions A/B/C); conv
/// problems are keyed by layer signature.
///
/// # Examples
///
/// ```
/// use portable_kernels::tuner::SelectionKey;
///
/// // Nearby GEMM shapes bucket to one power-of-two problem class...
/// let a = SelectionKey::gemm("host", 96, 96, 96);
/// let b = SelectionKey::gemm("host", 128, 100, 70);
/// assert_eq!(a, b);
/// assert_eq!(a.op, "gemm_128x128x128");
/// // ...but selections never leak across devices.
/// assert_ne!(a, SelectionKey::gemm("mali-g71", 96, 96, 96));
/// ```
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct SelectionKey {
    /// Device / platform namespace (`host` for measured host sweeps,
    /// paper device ids for the modeled zoo).
    pub device: String,
    /// Problem-class identifier, e.g. `gemm_128x128x128`.
    pub op: String,
}

impl SelectionKey {
    /// GEMM key: log2-bucketed M, N, K (the region structure of Fig. 5).
    pub fn gemm(device: &str, m: u64, n: u64, k: u64) -> Self {
        let b = |x: u64| 64u64.max(x.next_power_of_two());
        SelectionKey {
            device: device.to_string(),
            op: format!("gemm_{}x{}x{}", b(m), b(n), b(k)),
        }
    }

    /// Convolution key: the full layer signature.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        device: &str,
        window: u32,
        stride: u32,
        h: u32,
        w: u32,
        c: u32,
        k: u32,
        batch: u32,
    ) -> Self {
        SelectionKey {
            device: device.to_string(),
            op: format!("conv_{window}x{window}s{stride}_{h}x{w}x{c}k{k}b{batch}"),
        }
    }

    fn as_string(&self) -> String {
        format!("{}::{}", self.device, self.op)
    }
}

/// One stored selection, in its serialized shape: the kind string, the
/// full rendered JSON entry (written back verbatim by
/// [`SelectionDb::save`], so legacy entries survive a load/save cycle
/// untouched), and the measured/modeled throughput.
#[derive(Debug, Clone)]
pub struct StoredSelection {
    kind: String,
    entry: Value,
    gflops: f64,
}

impl StoredSelection {
    /// The entry's kind string — a space `KIND` (`gemm_point`,
    /// `conv_point`, `gemm`, `conv`) or a legacy kind (`blocked`,
    /// `conv_native`).
    pub fn kind(&self) -> &str {
        &self.kind
    }

    /// Throughput of the stored winner, GFLOP/s.
    pub fn gflops(&self) -> f64 {
        self.gflops
    }

    /// The full JSON entry as serialized (kind, point, name, report
    /// columns, gflops, and — when a sweep recorded it — the `search` /
    /// `points_measured` provenance columns).
    pub fn entry(&self) -> &Value {
        &self.entry
    }
}

/// Decode a stored entry under problem class `op` as a point of space
/// `P`: directly when the kind matches `P::KIND`, through the migration
/// shim when it is one of `P::LEGACY_KINDS` *and* the space accepts
/// that kind under this problem class
/// ([`KernelSpace::legacy_kind_applies`] — e.g. a GEMM-space entry
/// under a gemm key never answers a conv lookup), `None` otherwise (the
/// entry belongs to another space).  Entries were validated through
/// exactly these decoders at load/put time, so a `None` from a matching
/// kind cannot happen in practice.
fn decode_stored<P: KernelSpace>(s: &StoredSelection, op: &str) -> Option<P> {
    if s.kind == P::KIND {
        P::from_json(s.entry.get(P::POINT_FIELD)?).ok()
    } else if P::LEGACY_KINDS.contains(&s.kind.as_str())
        && P::legacy_kind_applies(&s.kind, op)
    {
        P::from_legacy_json(&s.kind, &s.entry).ok()
    } else {
        None
    }
}

/// What [`SelectionDb::merge`] did, per entry class.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MergeStats {
    /// Keys absent from the target DB: inserted.
    pub added: usize,
    /// Keys present with a slower same-kind entry: replaced by the
    /// faster one.
    pub replaced: usize,
    /// Keys present with an equal-or-faster same-kind entry: left
    /// alone.
    pub kept: usize,
    /// Entries whose legacy kind was rewritten into the unified schema
    /// while folding (counted across added + replaced).
    pub migrated: usize,
    /// Keys where the incoming kind (post-migration) differs from the
    /// stored one — e.g. a modeled `gemm` estimate colliding with a
    /// measured `gemm_point`.  Their throughput figures are not
    /// comparable (analytic estimates routinely dwarf measured
    /// numbers), so the target DB's entry is kept and the conflict
    /// counted instead of silently evicting a measured selection.
    pub kind_conflicts: usize,
}

/// Render the unified-schema JSON entry for a point (what [`put`] stores
/// and [`merge`] migrates legacy entries into).
///
/// [`put`]: SelectionDb::put
/// [`merge`]: SelectionDb::merge
fn render_entry<P: KernelSpace>(point: &P, gflops: f64) -> StoredSelection {
    let mut entry = Value::object();
    entry
        .set("kind", P::KIND)
        .set(P::POINT_FIELD, point.to_json())
        .set("name", point.point_name())
        .set("gflops", gflops);
    point.report_columns(&mut entry);
    StoredSelection { kind: P::KIND.to_string(), entry, gflops }
}

/// Validate a parsed entry at load time through the same decoders the
/// lookups use, so anything that loads is guaranteed to decode later.
///
/// NOTE: this kind→decoder mapping exists in two places that must
/// stay in sync when a space is added — here and in each space's
/// `KIND`/`LEGACY_KINDS` — all driven by the same four `KernelSpace`
/// impls, so drift shows up as a loud "bad kind" load error rather
/// than silent misdecoding.  Extra top-level fields (e.g. the search
/// provenance columns) are tolerated and round-trip verbatim.
fn validate_entry(key: &str, kind: &str, entry: &Value) -> Result<()> {
    let point = |field: &str| -> Result<&Value> {
        entry.get(field).ok_or_else(|| {
            Error::Json(format!("{key}: missing {field}"))
        })
    };
    let wrap = |r: Result<()>| -> Result<()> {
        r.map_err(|e| Error::Json(format!("{key}: {e}")))
    };
    match kind {
        k if k == <GemmConfig as KernelSpace>::KIND => wrap(
            GemmConfig::from_json(point(
                <GemmConfig as KernelSpace>::POINT_FIELD,
            )?)
            .map(drop),
        ),
        k if k == <ConvConfig as KernelSpace>::KIND => wrap(
            ConvConfig::from_json(point(
                <ConvConfig as KernelSpace>::POINT_FIELD,
            )?)
            .map(drop),
        ),
        k if k == GemmPoint::KIND => wrap(
            GemmPoint::from_json(point(GemmPoint::POINT_FIELD)?).map(drop),
        ),
        k if k == ConvPoint::KIND => wrap(
            ConvPoint::from_json(point(ConvPoint::POINT_FIELD)?).map(drop),
        ),
        "blocked" => {
            wrap(GemmPoint::from_legacy_json("blocked", entry).map(drop))
        }
        "conv_native" => {
            wrap(ConvPoint::from_legacy_json("conv_native", entry).map(drop))
        }
        other => Err(Error::Json(format!("{key}: bad kind {other:?}"))),
    }
}

/// The database: ordered map for stable serialization.
///
/// # Examples
///
/// ```
/// use portable_kernels::blas::BlockedParams;
/// use portable_kernels::config::GemmPoint;
/// use portable_kernels::tuner::{SelectionDb, SelectionKey};
///
/// let mut db = SelectionDb::new();
/// let key = SelectionKey::gemm("host", 96, 96, 96);
/// let winner = GemmPoint::scalar(
///     BlockedParams { threads: 2, ..BlockedParams::default() },
/// );
/// db.put(key.clone(), winner, 12.5);
///
/// // The same bucketed key answers lookups for nearby shapes.
/// let (point, gflops) = db
///     .get::<GemmPoint>(&SelectionKey::gemm("host", 128, 128, 128))
///     .unwrap();
/// assert_eq!(point, winner);
/// assert_eq!(gflops, 12.5);
/// ```
#[derive(Debug, Default, Clone)]
pub struct SelectionDb {
    entries: BTreeMap<String, StoredSelection>,
}

impl SelectionDb {
    /// An empty database.
    pub fn new() -> Self {
        Self::default()
    }

    /// Store a winning point of any [`KernelSpace`] for a problem class,
    /// in the unified schema (kind = the space's `KIND`).
    pub fn put<P: KernelSpace>(
        &mut self,
        key: SelectionKey,
        point: P,
        gflops: f64,
    ) {
        self.entries
            .insert(key.as_string(), render_entry(&point, gflops));
    }

    /// Look up the stored point of space `P` for a problem class:
    /// entries of kind `P::KIND` decode directly, entries of one of
    /// `P::LEGACY_KINDS` through the space's migration shim — gated on
    /// the problem class where the space demands it (GEMM-space entries
    /// answer conv lookups only under `conv_` keys) — and entries of
    /// any other kind answer `None` (they belong to a different space).
    pub fn get<P: KernelSpace>(
        &self,
        key: &SelectionKey,
    ) -> Option<(P, f64)> {
        let stored = self.entries.get(&key.as_string())?;
        decode_stored::<P>(stored, &key.op).map(|p| (p, stored.gflops))
    }

    /// The raw stored entry for a problem class, if any — kind string,
    /// gflops and entry JSON included.  This is how plan-time consumers
    /// distinguish a *migrated* legacy entry (kind in `P::LEGACY_KINDS`)
    /// from a native one: migration shims fill absent knobs with
    /// defaults (`threads: 0` = auto), and some defaults deserve
    /// plan-time clamping that a deliberately tuned value does not.
    pub fn stored(&self, key: &SelectionKey) -> Option<&StoredSelection> {
        self.entries.get(&key.as_string())
    }

    /// Stamp search provenance onto the stored entry for `key`: which
    /// strategy picked the winner (`search`) and how many grid points it
    /// actually measured for the class (`points_measured`).  No-op when
    /// the key has no entry.  The columns ride along as extra top-level
    /// fields — decoders ignore them, [`SelectionDb::save`] writes them
    /// verbatim, and reports read them to show the guided-vs-exhaustive
    /// measured-point savings.
    pub fn annotate_search(
        &mut self,
        key: &SelectionKey,
        search: &str,
        points_measured: usize,
    ) {
        if let Some(stored) = self.entries.get_mut(&key.as_string()) {
            stored
                .entry
                .set("search", search)
                .set("points_measured", points_measured as u64);
        }
    }

    /// Number of stored selections.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the database holds no selections.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate all entries in stored form, keyed `device::op` (for
    /// reports and warm-start scans; decode a specific space's point
    /// with [`SelectionDb::get`]).
    pub fn iter(&self) -> impl Iterator<Item = (&String, &StoredSelection)> {
        self.entries.iter()
    }

    /// Fold `other` into this DB, migrating legacy kinds to the unified
    /// schema and keeping the faster entry per key (`tune_device
    /// --merge OLD.json`).  Modeled zoo entries (`gemm` / `conv`) and
    /// already-unified entries copy through unchanged; `blocked` /
    /// `conv_native` entries are rewritten as `gemm_point` /
    /// `conv_point` while folding.  "Faster" is only meaningful within
    /// one kind: when the incoming entry's kind (post-migration)
    /// differs from the stored one — a modeled estimate vs a measured
    /// point — the stored entry is kept and the collision reported in
    /// [`MergeStats::kind_conflicts`], mirroring the conflicting-kind
    /// rejection [`SelectionDb::load`] applies to duplicate keys.
    pub fn merge(&mut self, other: &SelectionDb) -> MergeStats {
        let mut stats = MergeStats::default();
        for (key, stored) in &other.entries {
            let (incoming, migrated) = normalize_for_merge(key, stored);
            let existing =
                self.entries.get(key).map(|e| (e.kind.clone(), e.gflops));
            match existing {
                Some((kind, _)) if kind != incoming.kind => {
                    // Incomparable throughput figures (different
                    // spaces/modes): never silently evict; keep the
                    // target's entry and surface the collision.
                    stats.kind_conflicts += 1;
                }
                Some((_, g)) if g >= incoming.gflops => {
                    // The existing entry is equal-or-faster: keep it
                    // (the migration did not land, so it is not
                    // counted).
                    stats.kept += 1;
                }
                Some(_) => {
                    stats.replaced += 1;
                    stats.migrated += migrated as usize;
                    self.entries.insert(key.clone(), incoming);
                }
                None => {
                    stats.added += 1;
                    stats.migrated += migrated as usize;
                    self.entries.insert(key.clone(), incoming);
                }
            }
        }
        stats
    }

    fn to_json(&self) -> Value {
        let mut root = Value::object();
        for (k, stored) in &self.entries {
            root.set(k, stored.entry.clone());
        }
        root
    }

    fn from_json(v: &Value, dups: &[json::DuplicateKey]) -> Result<Self> {
        let obj = v
            .as_object()
            .ok_or_else(|| Error::Json("selection db must be an object".into()))?;
        // Duplicate top-level keys whose occurrences disagree on the
        // kind are ambiguous — two different spaces claim the same
        // problem class — and must fail loudly instead of silently
        // keeping whichever parsed last.
        for d in dups.iter().filter(|d| d.depth == 0) {
            let kept_kind = obj
                .get(&d.key)
                .and_then(|e| e.get("kind"))
                .and_then(|k| k.as_str());
            let overwritten_kind =
                d.overwritten.get("kind").and_then(|k| k.as_str());
            if kept_kind != overwritten_kind {
                return Err(Error::Json(format!(
                    "{}: duplicate key with conflicting kinds {:?} vs {:?}",
                    d.key, overwritten_kind, kept_kind
                )));
            }
        }
        let mut entries = BTreeMap::new();
        for (k, e) in obj {
            let gflops = e
                .get("gflops")
                .and_then(|x| x.as_f64())
                .ok_or_else(|| Error::Json(format!("{k}: missing gflops")))?;
            let kind = e
                .get("kind")
                .and_then(|x| x.as_str())
                .ok_or_else(|| Error::Json(format!("{k}: bad kind None")))?
                .to_string();
            validate_entry(k, &kind, e)?;
            entries.insert(
                k.clone(),
                StoredSelection { kind, entry: e.clone(), gflops },
            );
        }
        Ok(Self { entries })
    }

    /// Persist to `path` as pretty-printed JSON (atomic: write to a
    /// sibling `.tmp`, then rename).  Entries are written back exactly
    /// as stored, so a loaded legacy DB round-trips untouched.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_json_pretty())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load a database previously written by [`SelectionDb::save`] (or
    /// by any pre-unification version — legacy kinds validate through
    /// their migration shims).  Rejects unknown kinds, invalid points,
    /// and duplicate keys with conflicting kinds.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let (v, dups) = json::parse_tracking_duplicates(&text)
            .map_err(|e| Error::Json(e.to_string()))?;
        Self::from_json(&v, &dups)
    }
}

/// Rewrite one entry into the unified schema for [`SelectionDb::merge`]:
/// legacy measured kinds become `gemm_point` / `conv_point` (keyed on
/// the problem-class prefix for ambiguous `blocked` entries); everything
/// else copies through.  Returns the entry plus whether it was migrated.
fn normalize_for_merge(
    key: &str,
    stored: &StoredSelection,
) -> (StoredSelection, bool) {
    let op = key.split_once("::").map(|(_, op)| op).unwrap_or(key);
    match stored.kind.as_str() {
        "blocked" if op.starts_with("gemm_") => {
            match GemmPoint::from_legacy_json("blocked", &stored.entry) {
                Ok(p) => (render_entry(&p, stored.gflops), true),
                Err(_) => (stored.clone(), false),
            }
        }
        "blocked" | "conv_native" if op.starts_with("conv_") => {
            match ConvPoint::from_legacy_json(&stored.kind, &stored.entry) {
                Ok(p) => (render_entry(&p, stored.gflops), true),
                Err(_) => (stored.clone(), false),
            }
        }
        _ => (stored.clone(), false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{BlockedParams, Dtype, Isa, Pack};
    use crate::config::ConvAlgorithm;
    use crate::util::tmp::TempDir;

    #[test]
    fn gemm_keys_bucket_by_power_of_two() {
        let a = SelectionKey::gemm("mali-g71", 300, 300, 300);
        let b = SelectionKey::gemm("mali-g71", 500, 400, 280);
        assert_eq!(a, b); // both bucket to 512x512x512
        let c = SelectionKey::gemm("mali-g71", 700, 400, 280);
        assert_ne!(a, c);
        // Tiny shapes floor at the 64 bucket.
        let d = SelectionKey::gemm("mali-g71", 3, 5, 7);
        assert_eq!(d.op, "gemm_64x64x64");
    }

    #[test]
    fn keys_are_device_scoped() {
        let a = SelectionKey::gemm("mali-g71", 512, 512, 512);
        let b = SelectionKey::gemm("r9-nano", 512, 512, 512);
        assert_ne!(a, b);
    }

    #[test]
    fn roundtrip_via_disk() {
        let mut db = SelectionDb::new();
        db.put(
            SelectionKey::gemm("mali-g71", 512, 512, 512),
            GemmConfig::parse("8x4_4x8_noloc").unwrap(),
            42.0,
        );
        db.put(
            SelectionKey::conv("mali-g71", 3, 1, 56, 56, 64, 64, 1),
            ConvConfig::tiled(4, 4, 4, 2),
            33.0,
        );
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("selections.json");
        db.save(&path).unwrap();
        let loaded = SelectionDb::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        let (cfg, g) = loaded
            .get::<GemmConfig>(&SelectionKey::gemm("mali-g71", 512, 512, 512))
            .unwrap();
        assert_eq!(cfg.name(), "8x4_4x8_noloc");
        assert_eq!(g, 42.0);
        let (ccfg, _) = loaded
            .get::<ConvConfig>(&SelectionKey::conv(
                "mali-g71", 3, 1, 56, 56, 64, 64, 1,
            ))
            .unwrap();
        assert_eq!(ccfg.tile_h, 4);
        assert_eq!(ccfg.algorithm, ConvAlgorithm::Tiled);
        // The modeled kinds keep their historical serialized layout.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""kind": "gemm""#), "{text}");
        assert!(text.contains(r#""config": "8x4_4x8_noloc""#), "{text}");
    }

    #[test]
    fn roundtrip_gemm_point_with_isa_via_disk() {
        let mut db = SelectionDb::new();
        let gp = GemmPoint {
            params: BlockedParams {
                bm: 32, bn: 64, bk: 16, mr: 4, nr: 8, threads: 2,
            },
            isa: Isa::Avx2,
            dtype: Dtype::I8,
            pack: Pack::Ab,
        };
        let key = SelectionKey::gemm("host", 96, 96, 96);
        db.put(key.clone(), gp, 7.5);
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("host.json");
        db.save(&path).unwrap();
        // The entry carries the isa, dtype, and pack twice: inside the
        // point and as top-level report columns.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""kind": "gemm_point""#), "{text}");
        assert!(text.contains(r#""isa": "avx2""#), "{text}");
        assert!(text.contains(r#""dtype": "i8""#), "{text}");
        assert!(text.contains(r#""pack": "ab""#), "{text}");
        let loaded = SelectionDb::load(&path).unwrap();
        assert_eq!(loaded.get::<GemmPoint>(&key).unwrap(), (gp, 7.5));
        // A gemm_point entry never answers modeled or conv lookups.
        assert!(loaded.get::<GemmConfig>(&key).is_none());
        assert!(loaded.get::<ConvPoint>(&key).is_none());
    }

    #[test]
    fn annotations_survive_roundtrip_and_stay_invisible_to_decoders() {
        let mut db = SelectionDb::new();
        let key = SelectionKey::gemm("host", 96, 96, 96);
        db.put(key.clone(), GemmPoint::default(), 3.0);
        db.annotate_search(&key, "guided", 7);
        // Annotating a missing key is a quiet no-op.
        db.annotate_search(&SelectionKey::gemm("host", 4096, 64, 64), "x", 1);
        assert_eq!(db.len(), 1);
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("annotated.json");
        db.save(&path).unwrap();
        let loaded = SelectionDb::load(&path).unwrap();
        // The typed lookup is unaffected by the extra columns...
        let (p, g) = loaded.get::<GemmPoint>(&key).unwrap();
        assert_eq!((p, g), (GemmPoint::default(), 3.0));
        // ...and the provenance columns round-trip for reports.
        let entry = loaded.stored(&key).unwrap().entry();
        assert_eq!(
            entry.get("search").and_then(|v| v.as_str()),
            Some("guided")
        );
        assert_eq!(
            entry.get("points_measured").and_then(|v| v.as_u64()),
            Some(7)
        );
    }

    #[test]
    fn gemm_space_entries_never_answer_conv_lookups_under_gemm_keys() {
        // The blocked/gemm_point -> im2col migration is a *conv-key*
        // rule: under a gemm problem class those entries are GEMM
        // selections, and the conv space must not claim them.
        let gkey = SelectionKey::gemm("host", 64, 64, 64);
        let mut db = SelectionDb::new();
        db.put(gkey.clone(), GemmPoint::default(), 2.0);
        assert!(db.get::<GemmPoint>(&gkey).is_some());
        assert!(db.get::<ConvPoint>(&gkey).is_none());
        assert!(db.get::<ConvConfig>(&gkey).is_none());
        // Same for a legacy blocked entry under a gemm key.
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("gemm_blocked.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 1.0,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 1}}}"#,
        )
        .unwrap();
        let loaded = SelectionDb::load(&path).unwrap();
        assert!(loaded.get::<GemmPoint>(&gkey).is_some());
        assert!(loaded.get::<ConvPoint>(&gkey).is_none());
    }

    #[test]
    fn scalar_points_migrate_to_im2col_under_conv_keys() {
        let mut db = SelectionDb::new();
        let params = BlockedParams {
            bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 0,
        };
        let key = SelectionKey::conv("host", 3, 1, 16, 16, 8, 16, 2);
        db.put(key.clone(), GemmPoint::scalar(params), 3.25);
        let (p, g) = db.get::<GemmPoint>(&key).unwrap();
        assert_eq!((p.params, p.isa, g), (params, Isa::Scalar, 3.25));
        // Under a conv key, the conv space migrates it to im2col.
        let (cp, _) = db.get::<ConvPoint>(&key).unwrap();
        assert_eq!(cp.config.algorithm, ConvAlgorithm::Im2col);
        assert_eq!(cp.blocked, params);
    }

    #[test]
    fn roundtrip_conv_point_via_disk() {
        let mut db = SelectionDb::new();
        let cp = ConvPoint {
            config: ConvConfig::winograd(2),
            blocked: BlockedParams {
                bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 2,
            },
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            pack: Pack::Ab,
        };
        let key = SelectionKey::conv("host", 3, 1, 16, 16, 8, 16, 2);
        db.put(key.clone(), cp, 5.5);
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("convpoint.json");
        db.save(&path).unwrap();
        // The serialized entry carries the algorithm twice: once inside
        // the point, once as the top-level report column.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""kind": "conv_point""#), "{text}");
        assert!(text.contains(r#""algorithm": "winograd""#), "{text}");
        assert!(text.contains(r#""pack": "ab""#), "{text}");
        let loaded = SelectionDb::load(&path).unwrap();
        let (c, g) = loaded.get::<ConvPoint>(&key).unwrap();
        assert_eq!((c, g), (cp, 5.5));
        // A conv_point entry answers GEMM-space lookups with None.
        assert!(loaded.get::<GemmPoint>(&key).is_none());
        let (_, stored) = loaded.iter().next().unwrap();
        assert_eq!(stored.kind(), ConvPoint::KIND);
    }

    #[test]
    fn legacy_blocked_and_conv_native_fixtures_still_load() {
        // Byte-for-byte pre-unification DB JSON: both kinds must load,
        // answer the same lookups they always did, and round-trip
        // through save untouched.
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("legacy.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 2.5,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 1},
                "name": "bm8bn8bk8_2x2_t1"},
               "host::conv_3x3s1_8x8x4k4b1": {"kind": "conv_native",
                "gflops": 4.0, "algorithm": "winograd",
                "config": {"tile_h": 1, "tile_w": 1, "vec_c": 1,
                           "vec_k": 1, "block_k": 0,
                           "algorithm": "winograd", "wino_m": 2},
                "blocked": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                            "threads": 1}}}"#,
        )
        .unwrap();
        let db = SelectionDb::load(&path).unwrap();
        let gkey = SelectionKey::gemm("host", 64, 64, 64);
        let (gp, g) = db.get::<GemmPoint>(&gkey).unwrap();
        assert_eq!(g, 2.5);
        assert_eq!(gp.isa, Isa::Scalar, "legacy entries migrate as scalar");
        assert_eq!((gp.params.bm, gp.params.threads), (8, 1));
        assert_eq!(db.stored(&gkey).unwrap().kind(), "blocked");
        let ckey = SelectionKey::conv("host", 3, 1, 8, 8, 4, 4, 1);
        let (cp, _) = db.get::<ConvPoint>(&ckey).unwrap();
        assert_eq!(cp.config.algorithm, ConvAlgorithm::Winograd);
        // Round-trip: legacy entries are written back verbatim.
        let out = dir.path().join("resaved.json");
        db.save(&out).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(text.contains(r#""kind": "blocked""#), "{text}");
        assert!(text.contains(r#""kind": "conv_native""#), "{text}");
        assert_eq!(SelectionDb::load(&out).unwrap().len(), 2);
    }

    #[test]
    fn conv_native_invalid_config_rejected_on_load() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("bad_cn.json");
        // wino_m 3 is outside the supported set: load must fail loudly.
        std::fs::write(
            &path,
            r#"{"host::conv_3x3s1_8x8x4k4b1": {"kind": "conv_native",
                "gflops": 1.0,
                "config": {"tile_h": 1, "tile_w": 1, "vec_c": 1,
                           "vec_k": 1, "block_k": 0,
                           "algorithm": "winograd", "wino_m": 3},
                "blocked": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                            "threads": 1}}}"#,
        )
        .unwrap();
        assert!(SelectionDb::load(&path).is_err());
        // Missing the blocked half is just as fatal.
        std::fs::write(
            &path,
            r#"{"host::conv_3x3s1_8x8x4k4b1": {"kind": "conv_native",
                "gflops": 1.0,
                "config": {"tile_h": 1, "tile_w": 1, "vec_c": 1,
                           "vec_k": 1, "block_k": 0,
                           "algorithm": "tiled", "wino_m": 2}}}"#,
        )
        .unwrap();
        assert!(SelectionDb::load(&path).is_err());
    }

    #[test]
    fn blocked_zero_dim_rejected_on_load() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("bad_blocked.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 1.0,
                "config": {"bm": 0, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 1}}}"#,
        )
        .unwrap();
        assert!(SelectionDb::load(&path).is_err());
    }

    #[test]
    fn gemm_point_bad_isa_rejected_on_load() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("bad_isa.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "gemm_point", "gflops": 1.0,
                "point": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                          "threads": 1, "isa": "avx512vnni"}}}"#,
        )
        .unwrap();
        assert!(SelectionDb::load(&path).is_err());
    }

    #[test]
    fn pre_threads_blocked_entry_defaults_to_auto() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("old.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 1.0,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2}}}"#,
        )
        .unwrap();
        let db = SelectionDb::load(&path).unwrap();
        let (p, _) = db
            .get::<GemmPoint>(&SelectionKey::gemm("host", 64, 64, 64))
            .unwrap();
        assert_eq!(p.params.threads, 0);
    }

    #[test]
    fn duplicate_key_with_conflicting_kinds_rejected_on_load() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("dup.json");
        // The same problem class claimed by two different spaces: loud
        // error, not silent last-write-wins.
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 1.0,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 1}},
               "host::gemm_64x64x64": {"kind": "gemm", "gflops": 2.0,
                "config": "4x4_8x8_loc"}}"#,
        )
        .unwrap();
        let err = SelectionDb::load(&path).unwrap_err().to_string();
        assert!(err.contains("conflicting kinds"), "got: {err}");
        // Same key, same kind: tolerated (last write wins, as JSON
        // resolves it).
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 1.0,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 1}},
               "host::gemm_64x64x64": {"kind": "blocked", "gflops": 2.0,
                "config": {"bm": 16, "bn": 16, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 1}}}"#,
        )
        .unwrap();
        let db = SelectionDb::load(&path).unwrap();
        let (p, g) = db
            .get::<GemmPoint>(&SelectionKey::gemm("host", 64, 64, 64))
            .unwrap();
        assert_eq!((p.params.bm, g), (16, 2.0));
    }

    #[test]
    fn merge_folds_legacy_into_unified_keeping_faster() {
        // Target: a fresh unified sweep.
        let mut db = SelectionDb::new();
        let gkey = SelectionKey::gemm("host", 64, 64, 64);
        let ckey = SelectionKey::conv("host", 3, 1, 8, 8, 4, 4, 1);
        db.put(gkey.clone(), GemmPoint::default(), 3.0);

        // Source: a legacy DB — one slower gemm entry (kept out), one
        // conv_native entry for a key the target lacks (folded in,
        // migrated), one faster gemm entry for another key (folded in).
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("legacy.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 1.0,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 1}},
               "host::gemm_256x256x256": {"kind": "blocked", "gflops": 9.0,
                "config": {"bm": 64, "bn": 64, "bk": 64, "mr": 4, "nr": 8,
                           "threads": 2}},
               "host::conv_3x3s1_8x8x4k4b1": {"kind": "conv_native",
                "gflops": 4.0, "algorithm": "tiled",
                "config": {"tile_h": 2, "tile_w": 2, "vec_c": 1,
                           "vec_k": 4, "block_k": 0,
                           "algorithm": "tiled", "wino_m": 2},
                "blocked": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                            "threads": 1}}}"#,
        )
        .unwrap();
        let legacy = SelectionDb::load(&path).unwrap();
        let stats = db.merge(&legacy);
        assert_eq!(
            (stats.added, stats.replaced, stats.kept, stats.migrated),
            (2, 0, 1, 2)
        );
        assert_eq!(db.len(), 3);
        // The kept entry is the faster unified one.
        let (p, g) = db.get::<GemmPoint>(&gkey).unwrap();
        assert_eq!((p, g), (GemmPoint::default(), 3.0));
        // Folded entries are in the unified schema now.
        let out = dir.path().join("merged.json");
        db.save(&out).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(!text.contains(r#""kind": "blocked""#), "{text}");
        assert!(!text.contains(r#""kind": "conv_native""#), "{text}");
        assert!(text.contains(r#""kind": "gemm_point""#), "{text}");
        assert!(text.contains(r#""kind": "conv_point""#), "{text}");
        let (cp, _) = db.get::<ConvPoint>(&ckey).unwrap();
        assert_eq!(cp.config.algorithm, ConvAlgorithm::Tiled);
        // A slower legacy entry never overwrites a faster unified one,
        // and merging is idempotent.
        let stats2 = db.clone().merge(&legacy);
        assert_eq!(stats2.added, 0);
    }

    #[test]
    fn merge_never_evicts_across_kinds() {
        // A modeled estimate (analytic GFLOP/s, routinely far above
        // measured numbers) colliding with a measured point is an
        // incomparable pair: the target's entry survives and the
        // collision is counted — never a silent eviction.
        let key = SelectionKey::gemm("host", 64, 64, 64);
        let mut measured = SelectionDb::new();
        measured.put(key.clone(), GemmPoint::default(), 3.0);
        let mut modeled = SelectionDb::new();
        modeled.put(
            key.clone(),
            GemmConfig::parse("8x4_8x16_loc").unwrap(),
            900.0,
        );
        let stats = measured.merge(&modeled);
        assert_eq!(stats.kind_conflicts, 1);
        assert_eq!((stats.added, stats.replaced, stats.kept), (0, 0, 0));
        // The measured point still answers the engine's lookup.
        let (p, g) = measured.get::<GemmPoint>(&key).unwrap();
        assert_eq!((p, g), (GemmPoint::default(), 3.0));
        // The other direction keeps the modeled entry too (no silent
        // cross-kind replacement either way).
        let mut modeled2 = modeled.clone();
        let stats = modeled2.merge(&measured);
        assert_eq!(stats.kind_conflicts, 1);
        assert!(modeled2.get::<GemmConfig>(&key).is_some());
    }

    #[test]
    fn missing_key_is_none() {
        let db = SelectionDb::new();
        assert!(db
            .get::<GemmConfig>(&SelectionKey::gemm("host", 64, 64, 64))
            .is_none());
        assert!(db
            .get::<GemmPoint>(&SelectionKey::gemm("host", 64, 64, 64))
            .is_none());
    }

    #[test]
    fn corrupt_db_rejected() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("bad.json");
        std::fs::write(&path, "{\"x\": {\"kind\": \"nope\"}}").unwrap();
        assert!(SelectionDb::load(&path).is_err());
        std::fs::write(&path, "not json").unwrap();
        assert!(SelectionDb::load(&path).is_err());
    }
}
