//! Selection database: persisted (device, problem) -> winning config.
//!
//! This is the tuning artifact a deployment ships — the paper's "choosing
//! the combinations of kernel parameters that perform best on the
//! hardware", made durable.  JSON on disk (via [`crate::util::json`]);
//! the request path only does map lookups.

use std::collections::BTreeMap;
use std::path::Path;

use crate::blas::BlockedParams;
use crate::config::{ConvAlgorithm, ConvConfig, GemmConfig};
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Problem-class key.  GEMM problems are bucketed by size class so nearby
/// shapes share a selection (the paper's Fig. 5 regions A/B/C); conv
/// problems are keyed by layer signature.
///
/// # Examples
///
/// ```
/// use portable_kernels::tuner::SelectionKey;
///
/// // Nearby GEMM shapes bucket to one power-of-two problem class...
/// let a = SelectionKey::gemm("host", 96, 96, 96);
/// let b = SelectionKey::gemm("host", 128, 100, 70);
/// assert_eq!(a, b);
/// assert_eq!(a.op, "gemm_128x128x128");
/// // ...but selections never leak across devices.
/// assert_ne!(a, SelectionKey::gemm("mali-g71", 96, 96, 96));
/// ```
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct SelectionKey {
    /// Device / platform namespace (`host` for measured host sweeps,
    /// paper device ids for the modeled zoo).
    pub device: String,
    /// Problem-class identifier, e.g. `gemm_128x128x128`.
    pub op: String,
}

impl SelectionKey {
    /// GEMM key: log2-bucketed M, N, K (the region structure of Fig. 5).
    pub fn gemm(device: &str, m: u64, n: u64, k: u64) -> Self {
        let b = |x: u64| 64u64.max(x.next_power_of_two());
        SelectionKey {
            device: device.to_string(),
            op: format!("gemm_{}x{}x{}", b(m), b(n), b(k)),
        }
    }

    /// Convolution key: the full layer signature.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        device: &str,
        window: u32,
        stride: u32,
        h: u32,
        w: u32,
        c: u32,
        k: u32,
        batch: u32,
    ) -> Self {
        SelectionKey {
            device: device.to_string(),
            op: format!("conv_{window}x{window}s{stride}_{h}x{w}x{c}k{k}b{batch}"),
        }
    }

    fn as_string(&self) -> String {
        format!("{}::{}", self.device, self.op)
    }
}

/// One stored selection.
#[derive(Debug, Clone)]
pub enum Selection {
    /// A modeled device-zoo GEMM selection.
    Gemm {
        /// Winning kernel configuration.
        config: GemmConfig,
        /// Its modeled throughput, GFLOP/s.
        gflops: f64,
    },
    /// A modeled device-zoo convolution selection.
    Conv {
        /// Winning kernel configuration.
        config: ConvConfig,
        /// Its modeled throughput, GFLOP/s.
        gflops: f64,
    },
    /// A measured host-kernel selection: the winning
    /// [`BlockedParams`] × threads combination from a per-host sweep
    /// (`tuner::tune_blocked_sweep`), consulted by `NativeEngine` at
    /// plan time.
    Blocked {
        /// Winning blocking parameters (including `threads`).
        params: BlockedParams,
        /// Its measured throughput, GFLOP/s.
        gflops: f64,
    },
    /// A measured native convolution selection: the winning *algorithm*
    /// plus its knobs (`tuner::tune_conv_native_sweep`) — the
    /// [`ConvConfig`] names the algorithm (tiled/im2col/winograd) and
    /// its tile/vector parameters, the [`BlockedParams`] carry the
    /// im2col GEMM blocking and the `threads` knob every path honors.
    /// `NativeEngine` resolves conv plans from these first.
    ConvNative {
        /// Winning algorithm + tile/vector configuration.
        config: ConvConfig,
        /// Winning GEMM blocking (im2col path) and `threads`.
        blocked: BlockedParams,
        /// Its measured throughput, GFLOP/s.
        gflops: f64,
    },
}

fn blocked_to_json(p: &BlockedParams) -> Value {
    let mut o = Value::object();
    o.set("bm", p.bm)
        .set("bn", p.bn)
        .set("bk", p.bk)
        .set("mr", p.mr)
        .set("nr", p.nr)
        .set("threads", p.threads);
    o
}

fn blocked_from_json(v: &Value) -> Result<BlockedParams> {
    let field = |k: &str| -> Result<usize> {
        v.get(k)
            .and_then(|x| x.as_u64())
            .map(|x| x as usize)
            .ok_or_else(|| Error::Json(format!("blocked config missing {k}")))
    };
    let p = BlockedParams {
        bm: field("bm")?,
        bn: field("bn")?,
        bk: field("bk")?,
        mr: field("mr")?,
        nr: field("nr")?,
        // Absent threads (a pre-threads DB) means "auto".
        threads: v
            .get("threads")
            .and_then(|x| x.as_u64())
            .unwrap_or(0) as usize,
    };
    if p.bm == 0 || p.bn == 0 || p.bk == 0 || p.mr == 0 || p.nr == 0 {
        return Err(Error::Json(format!(
            "blocked config has a zero block dimension: {p:?}"
        )));
    }
    Ok(p)
}

fn conv_to_json(c: &ConvConfig) -> Value {
    let mut o = Value::object();
    o.set("tile_h", c.tile_h)
        .set("tile_w", c.tile_w)
        .set("vec_c", c.vec_c)
        .set("vec_k", c.vec_k)
        .set("block_k", c.block_k)
        .set("algorithm", c.algorithm.as_str())
        .set("wino_m", c.wino_m);
    o
}

fn conv_from_json(v: &Value) -> Result<ConvConfig> {
    let field = |k: &str| -> Result<u32> {
        v.get(k)
            .and_then(|x| x.as_u64())
            .map(|x| x as u32)
            .ok_or_else(|| Error::Json(format!("conv config missing {k}")))
    };
    Ok(ConvConfig {
        tile_h: field("tile_h")?,
        tile_w: field("tile_w")?,
        vec_c: field("vec_c")?,
        vec_k: field("vec_k")?,
        block_k: field("block_k")?,
        algorithm: v
            .get("algorithm")
            .and_then(|x| x.as_str())
            .ok_or_else(|| Error::Json("conv config missing algorithm".into()))?
            .parse::<ConvAlgorithm>()?,
        wino_m: field("wino_m")?,
    })
}

/// The database: ordered map for stable serialization.
///
/// # Examples
///
/// ```
/// use portable_kernels::blas::BlockedParams;
/// use portable_kernels::tuner::{SelectionDb, SelectionKey};
///
/// let mut db = SelectionDb::new();
/// let key = SelectionKey::gemm("host", 96, 96, 96);
/// let winner = BlockedParams { threads: 2, ..BlockedParams::default() };
/// db.put_blocked(key.clone(), winner, 12.5);
///
/// // The same bucketed key answers lookups for nearby shapes.
/// let (params, gflops) =
///     db.get_blocked(&SelectionKey::gemm("host", 128, 128, 128)).unwrap();
/// assert_eq!(params, winner);
/// assert_eq!(gflops, 12.5);
/// ```
#[derive(Debug, Default, Clone)]
pub struct SelectionDb {
    entries: BTreeMap<String, Selection>,
}

impl SelectionDb {
    /// An empty database.
    pub fn new() -> Self {
        Self::default()
    }

    /// Store a modeled GEMM selection for a problem class.
    pub fn put_gemm(&mut self, key: SelectionKey, config: GemmConfig, gflops: f64) {
        self.entries
            .insert(key.as_string(), Selection::Gemm { config, gflops });
    }

    /// Store a modeled convolution selection for a problem class.
    pub fn put_conv(&mut self, key: SelectionKey, config: ConvConfig, gflops: f64) {
        self.entries
            .insert(key.as_string(), Selection::Conv { config, gflops });
    }

    /// Look up a modeled GEMM selection (config + GFLOP/s).
    pub fn get_gemm(&self, key: &SelectionKey) -> Option<(GemmConfig, f64)> {
        match self.entries.get(&key.as_string()) {
            Some(Selection::Gemm { config, gflops }) => Some((*config, *gflops)),
            _ => None,
        }
    }

    /// Look up a modeled convolution selection (config + GFLOP/s).
    pub fn get_conv(&self, key: &SelectionKey) -> Option<(ConvConfig, f64)> {
        match self.entries.get(&key.as_string()) {
            Some(Selection::Conv { config, gflops }) => Some((*config, *gflops)),
            _ => None,
        }
    }

    /// Store a measured host selection ([`BlockedParams`] × threads) for
    /// a problem class.  The key is the same `gemm`/`conv` key the
    /// modeled selections use, with the platform as the device.
    pub fn put_blocked(
        &mut self,
        key: SelectionKey,
        params: BlockedParams,
        gflops: f64,
    ) {
        self.entries
            .insert(key.as_string(), Selection::Blocked { params, gflops });
    }

    /// Look up a measured host selection (params + GFLOP/s).
    pub fn get_blocked(
        &self,
        key: &SelectionKey,
    ) -> Option<(BlockedParams, f64)> {
        match self.entries.get(&key.as_string()) {
            Some(Selection::Blocked { params, gflops }) => {
                Some((*params, *gflops))
            }
            _ => None,
        }
    }

    /// Store a measured native conv selection (algorithm + knobs) for a
    /// problem class.
    pub fn put_conv_native(
        &mut self,
        key: SelectionKey,
        config: ConvConfig,
        blocked: BlockedParams,
        gflops: f64,
    ) {
        self.entries.insert(
            key.as_string(),
            Selection::ConvNative { config, blocked, gflops },
        );
    }

    /// Look up a measured native conv selection
    /// (config + blocked + GFLOP/s).
    pub fn get_conv_native(
        &self,
        key: &SelectionKey,
    ) -> Option<(ConvConfig, BlockedParams, f64)> {
        match self.entries.get(&key.as_string()) {
            Some(Selection::ConvNative { config, blocked, gflops }) => {
                Some((*config, *blocked, *gflops))
            }
            _ => None,
        }
    }

    /// Number of stored selections.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the database holds no selections.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate all entries (for reports).
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Selection)> {
        self.entries.iter()
    }

    fn to_json(&self) -> Value {
        let mut root = Value::object();
        for (k, sel) in &self.entries {
            let mut o = Value::object();
            match sel {
                Selection::Gemm { config, gflops } => {
                    o.set("kind", "gemm")
                        .set("config", config.name())
                        .set("gflops", *gflops);
                }
                Selection::Conv { config, gflops } => {
                    o.set("kind", "conv")
                        .set("config", conv_to_json(config))
                        .set("gflops", *gflops);
                }
                Selection::Blocked { params, gflops } => {
                    o.set("kind", "blocked")
                        .set("config", blocked_to_json(params))
                        .set("name", params.name())
                        .set("gflops", *gflops);
                }
                Selection::ConvNative { config, blocked, gflops } => {
                    // The top-level "algorithm" duplicates
                    // config.algorithm so reports (and the CI check) can
                    // read the chosen algorithm without digging.
                    o.set("kind", "conv_native")
                        .set("algorithm", config.algorithm.as_str())
                        .set("config", conv_to_json(config))
                        .set("blocked", blocked_to_json(blocked))
                        .set(
                            "name",
                            format!("{}+{}", config.name(), blocked.name()),
                        )
                        .set("gflops", *gflops);
                }
            }
            root.set(k, o);
        }
        root
    }

    fn from_json(v: &Value) -> Result<Self> {
        let obj = v
            .as_object()
            .ok_or_else(|| Error::Json("selection db must be an object".into()))?;
        let mut entries = BTreeMap::new();
        for (k, e) in obj {
            let gflops = e
                .get("gflops")
                .and_then(|x| x.as_f64())
                .ok_or_else(|| Error::Json(format!("{k}: missing gflops")))?;
            let sel = match e.get("kind").and_then(|x| x.as_str()) {
                Some("gemm") => Selection::Gemm {
                    config: GemmConfig::parse(
                        e.get("config").and_then(|x| x.as_str()).ok_or_else(
                            || Error::Json(format!("{k}: missing config")),
                        )?,
                    )?,
                    gflops,
                },
                Some("conv") => Selection::Conv {
                    config: conv_from_json(e.get("config").ok_or_else(
                        || Error::Json(format!("{k}: missing config")),
                    )?)?,
                    gflops,
                },
                Some("blocked") => Selection::Blocked {
                    params: blocked_from_json(e.get("config").ok_or_else(
                        || Error::Json(format!("{k}: missing config")),
                    )?)?,
                    gflops,
                },
                Some("conv_native") => {
                    let config = conv_from_json(e.get("config").ok_or_else(
                        || Error::Json(format!("{k}: missing config")),
                    )?)?;
                    config.validate().map_err(|err| {
                        Error::Json(format!("{k}: {err}"))
                    })?;
                    Selection::ConvNative {
                        config,
                        blocked: blocked_from_json(
                            e.get("blocked").ok_or_else(|| {
                                Error::Json(format!("{k}: missing blocked"))
                            })?,
                        )?,
                        gflops,
                    }
                }
                other => {
                    return Err(Error::Json(format!("{k}: bad kind {other:?}")))
                }
            };
            entries.insert(k.clone(), sel);
        }
        Ok(Self { entries })
    }

    /// Persist to `path` as pretty-printed JSON (atomic: write to a
    /// sibling `.tmp`, then rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_json_pretty())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load a database previously written by [`SelectionDb::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let v = json::parse(&text).map_err(|e| Error::Json(e.to_string()))?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn gemm_keys_bucket_by_power_of_two() {
        let a = SelectionKey::gemm("mali-g71", 300, 300, 300);
        let b = SelectionKey::gemm("mali-g71", 500, 400, 280);
        assert_eq!(a, b); // both bucket to 512x512x512
        let c = SelectionKey::gemm("mali-g71", 700, 400, 280);
        assert_ne!(a, c);
        // Tiny shapes floor at the 64 bucket.
        let d = SelectionKey::gemm("mali-g71", 3, 5, 7);
        assert_eq!(d.op, "gemm_64x64x64");
    }

    #[test]
    fn keys_are_device_scoped() {
        let a = SelectionKey::gemm("mali-g71", 512, 512, 512);
        let b = SelectionKey::gemm("r9-nano", 512, 512, 512);
        assert_ne!(a, b);
    }

    #[test]
    fn roundtrip_via_disk() {
        let mut db = SelectionDb::new();
        db.put_gemm(
            SelectionKey::gemm("mali-g71", 512, 512, 512),
            GemmConfig::parse("8x4_4x8_noloc").unwrap(),
            42.0,
        );
        db.put_conv(
            SelectionKey::conv("mali-g71", 3, 1, 56, 56, 64, 64, 1),
            ConvConfig::tiled(4, 4, 4, 2),
            33.0,
        );
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("selections.json");
        db.save(&path).unwrap();
        let loaded = SelectionDb::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        let (cfg, g) = loaded
            .get_gemm(&SelectionKey::gemm("mali-g71", 512, 512, 512))
            .unwrap();
        assert_eq!(cfg.name(), "8x4_4x8_noloc");
        assert_eq!(g, 42.0);
        let (ccfg, _) = loaded
            .get_conv(&SelectionKey::conv("mali-g71", 3, 1, 56, 56, 64, 64, 1))
            .unwrap();
        assert_eq!(ccfg.tile_h, 4);
        assert_eq!(ccfg.algorithm, ConvAlgorithm::Tiled);
    }

    #[test]
    fn roundtrip_blocked_via_disk() {
        let mut db = SelectionDb::new();
        let gp = BlockedParams {
            bm: 32, bn: 64, bk: 16, mr: 4, nr: 8, threads: 2,
        };
        let cp = BlockedParams {
            bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 0,
        };
        db.put_blocked(SelectionKey::gemm("host", 96, 96, 96), gp, 7.5);
        db.put_blocked(
            SelectionKey::conv("host", 3, 1, 16, 16, 8, 16, 2),
            cp,
            3.25,
        );
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("host.json");
        db.save(&path).unwrap();
        let loaded = SelectionDb::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        let (p, g) = loaded
            .get_blocked(&SelectionKey::gemm("host", 96, 96, 96))
            .unwrap();
        assert_eq!(p, gp);
        assert_eq!(g, 7.5);
        let (p, _) = loaded
            .get_blocked(&SelectionKey::conv("host", 3, 1, 16, 16, 8, 16, 2))
            .unwrap();
        assert_eq!(p, cp);
        // A blocked entry never answers gemm/conv lookups and vice versa.
        assert!(loaded
            .get_gemm(&SelectionKey::gemm("host", 96, 96, 96))
            .is_none());
    }

    #[test]
    fn roundtrip_conv_native_via_disk() {
        let mut db = SelectionDb::new();
        let cfg = ConvConfig::winograd(2);
        let blk = BlockedParams {
            bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 2,
        };
        let key = SelectionKey::conv("host", 3, 1, 16, 16, 8, 16, 2);
        db.put_conv_native(key.clone(), cfg, blk, 5.5);
        db.put_conv_native(
            SelectionKey::conv("host", 3, 1, 32, 32, 16, 32, 2),
            ConvConfig::tiled(2, 2, 1, 4),
            BlockedParams::default(),
            7.75,
        );
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("convnative.json");
        db.save(&path).unwrap();
        // The serialized entry carries the algorithm twice: once inside
        // the config, once as the top-level report column.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""kind": "conv_native""#), "{text}");
        assert!(text.contains(r#""algorithm": "winograd""#), "{text}");
        let loaded = SelectionDb::load(&path).unwrap();
        let (c, b, g) = loaded.get_conv_native(&key).unwrap();
        assert_eq!(c, cfg);
        assert_eq!(b, blk);
        assert_eq!(g, 5.5);
        // A conv_native entry never answers blocked/conv lookups.
        assert!(loaded.get_blocked(&key).is_none());
        assert!(loaded.get_conv(&key).is_none());
    }

    #[test]
    fn conv_native_invalid_config_rejected_on_load() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("bad_cn.json");
        // wino_m 3 is outside the supported set: load must fail loudly.
        std::fs::write(
            &path,
            r#"{"host::conv_3x3s1_8x8x4k4b1": {"kind": "conv_native",
                "gflops": 1.0,
                "config": {"tile_h": 1, "tile_w": 1, "vec_c": 1,
                           "vec_k": 1, "block_k": 0,
                           "algorithm": "winograd", "wino_m": 3},
                "blocked": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                            "threads": 1}}}"#,
        )
        .unwrap();
        assert!(SelectionDb::load(&path).is_err());
        // Missing the blocked half is just as fatal.
        std::fs::write(
            &path,
            r#"{"host::conv_3x3s1_8x8x4k4b1": {"kind": "conv_native",
                "gflops": 1.0,
                "config": {"tile_h": 1, "tile_w": 1, "vec_c": 1,
                           "vec_k": 1, "block_k": 0,
                           "algorithm": "tiled", "wino_m": 2}}}"#,
        )
        .unwrap();
        assert!(SelectionDb::load(&path).is_err());
    }

    #[test]
    fn blocked_zero_dim_rejected_on_load() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("bad_blocked.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 1.0,
                "config": {"bm": 0, "bn": 8, "bk": 8, "mr": 2, "nr": 2,
                           "threads": 1}}}"#,
        )
        .unwrap();
        assert!(SelectionDb::load(&path).is_err());
    }

    #[test]
    fn pre_threads_blocked_entry_defaults_to_auto() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("old.json");
        std::fs::write(
            &path,
            r#"{"host::gemm_64x64x64": {"kind": "blocked", "gflops": 1.0,
                "config": {"bm": 8, "bn": 8, "bk": 8, "mr": 2, "nr": 2}}}"#,
        )
        .unwrap();
        let db = SelectionDb::load(&path).unwrap();
        let (p, _) = db
            .get_blocked(&SelectionKey::gemm("host", 64, 64, 64))
            .unwrap();
        assert_eq!(p.threads, 0);
    }

    #[test]
    fn missing_key_is_none() {
        let db = SelectionDb::new();
        assert!(db
            .get_gemm(&SelectionKey::gemm("host", 64, 64, 64))
            .is_none());
        assert!(db
            .get_blocked(&SelectionKey::gemm("host", 64, 64, 64))
            .is_none());
    }

    #[test]
    fn corrupt_db_rejected() {
        let dir = TempDir::new("seldb").unwrap();
        let path = dir.path().join("bad.json");
        std::fs::write(&path, "{\"x\": {\"kind\": \"nope\"}}").unwrap();
        assert!(SelectionDb::load(&path).is_err());
        std::fs::write(&path, "not json").unwrap();
        assert!(SelectionDb::load(&path).is_err());
    }
}
