//! Search strategies over kernel configuration spaces.

use crate::config::{conv_space, gemm_space, ConvConfig, GemmConfig};
use crate::device::DeviceSpec;
use crate::nn::ConvLayer;
use crate::perfmodel::{conv_estimate, gemm_estimate, ConvProblem, GemmProblem};

/// Outcome of tuning one problem on one device.
#[derive(Debug, Clone)]
pub struct TuneResult<C> {
    /// Winning configuration.
    pub config: C,
    /// Its modeled (or measured) GFLOP/s.
    pub gflops: f64,
    /// Configurations evaluated.
    pub evaluated: usize,
    /// Configurations rejected as infeasible on the device.
    pub infeasible: usize,
}

/// A search strategy over an indexable candidate list.
pub trait SearchStrategy {
    /// Pick the index of the best candidate given a scoring function
    /// returning `None` for infeasible candidates.  Returns the chosen
    /// index, the number of evaluations spent, and the best score.
    fn search(
        &self,
        n_candidates: usize,
        score: &mut dyn FnMut(usize) -> Option<f64>,
    ) -> Option<(usize, usize, f64)>;
}

/// Evaluate every candidate (the paper's offline-tuning mode).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExhaustiveSearch;

impl SearchStrategy for ExhaustiveSearch {
    fn search(
        &self,
        n: usize,
        score: &mut dyn FnMut(usize) -> Option<f64>,
    ) -> Option<(usize, usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..n {
            if let Some(s) = score(i) {
                if best.map(|(_, b)| s > b).unwrap_or(true) {
                    best = Some((i, s));
                }
            }
        }
        best.map(|(i, s)| (i, n, s))
    }
}

/// Evaluate a random subset (cheap screening for huge spaces).
/// Deterministic for a given seed.
#[derive(Debug, Clone, Copy)]
pub struct RandomSearch {
    /// How many candidates to sample (capped at the space size).
    pub samples: usize,
    /// RNG seed; identical seeds reproduce the search exactly.
    pub seed: u64,
}

impl SearchStrategy for RandomSearch {
    fn search(
        &self,
        n: usize,
        score: &mut dyn FnMut(usize) -> Option<f64>,
    ) -> Option<(usize, usize, f64)> {
        let mut state = self.seed | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state % n as u64) as usize
        };
        let mut best: Option<(usize, f64)> = None;
        let samples = self.samples.min(n);
        for _ in 0..samples {
            let i = next();
            if let Some(s) = score(i) {
                if best.map(|(_, b)| s > b).unwrap_or(true) {
                    best = Some((i, s));
                }
            }
        }
        best.map(|(i, s)| (i, samples, s))
    }
}

/// Random restarts + greedy neighbourhood walk; the "ML-ish" strategy the
/// paper leaves as future work, kept deterministic for reproducibility.
///
/// # Examples
///
/// ```
/// use portable_kernels::tuner::{HillClimb, SearchStrategy};
///
/// // Climb a simple unimodal score over 100 candidates.
/// let strategy = HillClimb { restarts: 4, seed: 7 };
/// let (best, evals, score) = strategy
///     .search(100, &mut |i| Some(-(i as f64 - 60.0).abs()))
///     .unwrap();
/// assert_eq!(best, 60);
/// assert_eq!(score, 0.0);
/// // ...in far fewer evaluations than the exhaustive 100.
/// assert!(evals < 100);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct HillClimb {
    /// Number of random restart points.
    pub restarts: usize,
    /// RNG seed; identical seeds reproduce the search exactly.
    pub seed: u64,
}

impl SearchStrategy for HillClimb {
    fn search(
        &self,
        n: usize,
        score: &mut dyn FnMut(usize) -> Option<f64>,
    ) -> Option<(usize, usize, f64)> {
        if n == 0 {
            return None;
        }
        let mut state = self.seed | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state % n as u64) as usize
        };
        let mut cache: Vec<Option<Option<f64>>> = vec![None; n];
        let mut evals = 0usize;
        let mut eval = |i: usize, cache: &mut Vec<Option<Option<f64>>>,
                        evals: &mut usize| {
            if cache[i].is_none() {
                *evals += 1;
                cache[i] = Some(score(i));
            }
            cache[i].unwrap()
        };
        let mut best: Option<(usize, f64)> = None;
        for _ in 0..self.restarts {
            let mut cur = next();
            let mut cur_score = match eval(cur, &mut cache, &mut evals) {
                Some(s) => s,
                None => continue,
            };
            // Greedy walk over the index neighbourhood (candidate lists
            // are generated in lexicographic parameter order, so +-1 are
            // parameter neighbours).
            loop {
                let mut improved = false;
                for cand in [cur.wrapping_sub(1), cur + 1, cur + 3, cur.wrapping_sub(3)] {
                    if cand < n {
                        if let Some(s) = eval(cand, &mut cache, &mut evals) {
                            if s > cur_score {
                                cur = cand;
                                cur_score = s;
                                improved = true;
                            }
                        }
                    }
                }
                if !improved {
                    break;
                }
            }
            if best.map(|(_, b)| cur_score > b).unwrap_or(true) {
                best = Some((cur, cur_score));
            }
        }
        best.map(|(i, s)| (i, evals, s))
    }
}

/// Tune GEMM for a problem on a device using the analytic model.
pub fn tune_gemm(
    dev: &DeviceSpec,
    p: GemmProblem,
    strategy: &dyn SearchStrategy,
) -> Option<TuneResult<GemmConfig>> {
    let space = gemm_space();
    let mut infeasible = 0usize;
    let mut score = |i: usize| match gemm_estimate(dev, p, &space[i]) {
        Ok(e) => Some(e.gflops),
        Err(_) => {
            infeasible += 1;
            None
        }
    };
    let (idx, evaluated, gflops) = strategy.search(space.len(), &mut score)?;
    Some(TuneResult {
        config: space[idx],
        gflops,
        evaluated,
        infeasible,
    })
}

/// Tune a convolution layer on a device using the analytic model.
/// The GEMM configuration feeding im2col/Winograd is itself tuned first.
pub fn tune_conv(
    dev: &DeviceSpec,
    layer: &ConvLayer,
    batch: u32,
    strategy: &dyn SearchStrategy,
) -> Option<TuneResult<ConvConfig>> {
    let (gm, gn, gk) = layer.im2col_gemm(batch);
    let gemm_cfg = tune_gemm(dev, GemmProblem::new(gm, gn, gk), strategy)
        .map(|r| r.config)
        .unwrap_or_default();

    let space = conv_space(layer.window, layer.stride);
    let p = ConvProblem::new(layer.clone(), batch);
    let mut infeasible = 0usize;
    let mut score = |i: usize| match conv_estimate(dev, &p, &space[i], &gemm_cfg)
    {
        Ok(e) => Some(e.gflops),
        Err(_) => {
            infeasible += 1;
            None
        }
    };
    let (idx, evaluated, gflops) = strategy.search(space.len(), &mut score)?;
    Some(TuneResult {
        config: space[idx],
        gflops,
        evaluated,
        infeasible,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::device_by_name;

    #[test]
    fn exhaustive_finds_global_argmax() {
        let scores = [1.0, 5.0, 3.0, 5.5, 0.5];
        let mut f = |i: usize| Some(scores[i]);
        let (idx, evals, best) =
            ExhaustiveSearch.search(scores.len(), &mut f).unwrap();
        assert_eq!(idx, 3);
        assert_eq!(evals, 5);
        assert_eq!(best, 5.5);
    }

    #[test]
    fn exhaustive_skips_infeasible() {
        let mut f = |i: usize| if i == 2 { Some(1.0) } else { None };
        let (idx, _, _) = ExhaustiveSearch.search(5, &mut f).unwrap();
        assert_eq!(idx, 2);
        let mut none = |_: usize| None;
        assert!(ExhaustiveSearch.search(5, &mut none).is_none());
    }

    #[test]
    fn random_search_is_deterministic() {
        let mut f1 = |i: usize| Some(i as f64);
        let mut f2 = |i: usize| Some(i as f64);
        let s = RandomSearch { samples: 10, seed: 42 };
        assert_eq!(s.search(100, &mut f1), s.search(100, &mut f2));
    }

    #[test]
    fn hill_climb_never_worse_than_its_start_samples() {
        // On a smooth landscape it should land near the peak.
        let mut f = |i: usize| {
            let x = i as f64 / 99.0;
            Some(-(x - 0.7) * (x - 0.7))
        };
        let (idx, _, _) = HillClimb { restarts: 8, seed: 7 }
            .search(100, &mut f)
            .unwrap();
        assert!((idx as i64 - 70).abs() <= 5, "landed at {idx}");
    }

    #[test]
    fn tune_gemm_beats_fixed_default() {
        let dev = device_by_name("mali-g71").unwrap();
        let p = GemmProblem::new(512, 512, 512);
        let tuned = tune_gemm(&dev, p, &ExhaustiveSearch).unwrap();
        let default = crate::perfmodel::gemm_estimate(
            &dev, p, &GemmConfig::default()
        ).unwrap();
        assert!(tuned.gflops >= default.gflops);
        assert!(tuned.evaluated > 100);
    }

    #[test]
    fn tuned_configs_differ_across_devices() {
        // The paper's core claim: different hardware picks different
        // parameters.  Tuned Mali (no local mem) and R9 Nano (big LDS)
        // winners should differ in at least one parameter.
        let p = GemmProblem::new(1024, 1024, 1024);
        let mali = tune_gemm(&device_by_name("mali-g71").unwrap(), p,
                             &ExhaustiveSearch).unwrap();
        let amd = tune_gemm(&device_by_name("r9-nano").unwrap(), p,
                            &ExhaustiveSearch).unwrap();
        assert_ne!(mali.config, amd.config,
                   "expected device-specific winners, both chose {}",
                   mali.config.name());
        // Mali must not stage through (emulated) local memory.
        assert!(!mali.config.use_local);
    }

    #[test]
    fn tune_conv_picks_winograd_for_heavy_3x3() {
        let dev = device_by_name("uhd630").unwrap();
        let layer = crate::nn::ConvLayer::same("t", 3, 1, 56, 56, 256, 256);
        let r = tune_conv(&dev, &layer, 4, &ExhaustiveSearch).unwrap();
        assert_eq!(
            r.config.algorithm,
            crate::config::ConvAlgorithm::Winograd,
            "picked {:?}", r.config
        );
    }

    #[test]
    fn tune_conv_never_picks_winograd_for_pointwise() {
        let dev = device_by_name("uhd630").unwrap();
        let layer = crate::nn::ConvLayer::same("t", 1, 1, 28, 28, 256, 512);
        let r = tune_conv(&dev, &layer, 4, &ExhaustiveSearch).unwrap();
        assert_ne!(r.config.algorithm, crate::config::ConvAlgorithm::Winograd);
    }
}
