//! Search strategies over kernel configuration spaces, unified behind
//! one **propose → measure → refine** lifecycle.
//!
//! Every strategy answers three questions: which candidates to measure
//! first ([`SearchStrategy::propose`] — possibly consulting a cost
//! model through the `rank` hook), which neighbours to try around the
//! measured winner ([`SearchStrategy::refine`]), and how many
//! measurements it may spend at most ([`SearchStrategy::max_evals`]).
//! The provided driver [`SearchStrategy::search_ranked`] runs the
//! lifecycle with memoized scoring, so strategies never re-measure a
//! candidate; [`SearchStrategy::search`] is the unranked entry point
//! the modeled zoo uses.
//!
//! [`GuidedSearch`] is the model-guided strategy: it measures only the
//! [`CostRanker`]'s top-ranked candidates plus every *pinned* incumbent
//! (the untuned default, the stored winner, warm-start seeds), then
//! hill-climbs around the measured winner — ≥10× fewer measured points
//! than the exhaustive grid at equal-or-better tuned throughput is the
//! contract CI's `tune-smoke` job asserts.

use crate::config::{
    conv_space, gemm_space, ConvConfig, GemmConfig, KernelSpace, Problem,
};
use crate::device::DeviceSpec;
use crate::nn::ConvLayer;
use crate::perfmodel::{conv_estimate, gemm_estimate, ConvProblem, GemmProblem};

/// Outcome of tuning one problem on one device.
#[derive(Debug, Clone)]
pub struct TuneResult<C> {
    /// Winning configuration.
    pub config: C,
    /// Its modeled (or measured) GFLOP/s.
    pub gflops: f64,
    /// Configurations evaluated.
    pub evaluated: usize,
    /// Configurations rejected as infeasible on the device.
    pub infeasible: usize,
}

/// Maps a candidate point plus its [`Problem`] to a predicted relative
/// cost — the pluggable model half of guided search.  Lower means
/// predicted-faster; `None` means the model cannot rank the point,
/// which [`GuidedSearch`] treats as worst-rank (measured only after
/// every modeled candidate), so pruning stays conservative: an
/// unmodeled candidate is deprioritized, never silently dropped ahead
/// of modeled ones.
pub trait CostRanker<P> {
    /// Predicted relative cost of `point` on `problem` (lower =
    /// predicted faster), or `None` if the model cannot rank it.
    fn rank(&self, point: &P, problem: &Problem) -> Option<f64>;
}

/// The analytic-model ranker: delegates to
/// [`KernelSpace::rank_hint`], i.e. the `perfmodel` per-point cost
/// queries (`perfmodel::point_cost`).  Spaces without a per-point model
/// (the modeled zoo configs) answer `None` for every point, and guided
/// search degrades to measuring in grid order under its budget.
#[derive(Debug, Default, Clone, Copy)]
pub struct ModelRanker;

impl<P: KernelSpace> CostRanker<P> for ModelRanker {
    fn rank(&self, point: &P, problem: &Problem) -> Option<f64> {
        point.rank_hint(problem)
    }
}

/// A search strategy over an indexable candidate list.
///
/// Implementations supply the *policy* hooks ([`name`], [`propose`],
/// optionally [`refine`] and [`max_evals`]); the provided
/// [`search_ranked`] driver owns the *mechanism* — memoized evaluation,
/// the refinement loop, and the measurement cap — so every strategy
/// measures each candidate at most once and all entry points
/// (`tune_space_sweep`, `retune_pass`, the modeled `tune_gemm` /
/// `tune_conv`) route through the same lifecycle.
///
/// [`name`]: SearchStrategy::name
/// [`propose`]: SearchStrategy::propose
/// [`refine`]: SearchStrategy::refine
/// [`max_evals`]: SearchStrategy::max_evals
/// [`search_ranked`]: SearchStrategy::search_ranked
pub trait SearchStrategy {
    /// Stable strategy name for reports (`tuning_host.json` and
    /// `BENCH_ci.json` carry it in their `search` column).
    fn name(&self) -> &'static str;

    /// The ordered candidate list to measure.  `pinned` indices (the
    /// untuned default, the stored incumbent, warm-start seeds) must be
    /// kept — strategies put them first so a budget cap can never drop
    /// them in favour of speculative candidates.  `rank` is the cost
    /// model's prediction (lower = faster, `None` = unmodeled); model-
    /// blind strategies ignore it.
    fn propose(
        &self,
        n: usize,
        pinned: &[usize],
        rank: &dyn Fn(usize) -> Option<f64>,
    ) -> Vec<usize>;

    /// Neighbour candidates to try around the current measured winner.
    /// The driver calls this repeatedly while refinement improves the
    /// winner.  Default: no refinement.  Out-of-range indices are
    /// filtered by the driver, so `best ± k` neighbourhoods need no
    /// bounds checks.
    fn refine(&self, best: usize, n: usize) -> Vec<usize> {
        let _ = (best, n);
        Vec::new()
    }

    /// Hard cap on measured candidates (proposals + refinement), or
    /// `None` for unbounded.  The driver stops measuring — proposals
    /// and neighbours alike — once the cap is reached.
    fn max_evals(&self) -> Option<usize> {
        None
    }

    /// The propose → measure → refine driver.  Measures the proposed
    /// candidates (memoized, capped by [`SearchStrategy::max_evals`]),
    /// then repeatedly measures [`SearchStrategy::refine`] neighbours of
    /// the winner while that improves it.  Returns the winning index,
    /// the number of *fresh* evaluations spent, and the best score
    /// (higher is better); `None` if nothing scored feasibly.
    fn search_ranked(
        &self,
        n: usize,
        pinned: &[usize],
        rank: &dyn Fn(usize) -> Option<f64>,
        score: &mut dyn FnMut(usize) -> Option<f64>,
    ) -> Option<(usize, usize, f64)> {
        if n == 0 {
            return None;
        }
        let cap = self.max_evals().unwrap_or(usize::MAX).max(1);
        let mut cache: Vec<Option<Option<f64>>> = vec![None; n];
        let mut evals = 0usize;
        let mut best: Option<(usize, f64)> = None;
        for i in self.propose(n, pinned, rank) {
            if i >= n {
                continue;
            }
            if cache[i].is_none() {
                if evals >= cap {
                    break;
                }
                evals += 1;
                cache[i] = Some(score(i));
            }
            if let Some(Some(s)) = cache[i] {
                if best.map(|(_, b)| s > b).unwrap_or(true) {
                    best = Some((i, s));
                }
            }
        }
        let (mut best_i, mut best_s) = best?;
        loop {
            let mut improved = false;
            let neighbours = self.refine(best_i, n);
            if neighbours.is_empty() {
                break;
            }
            for i in neighbours {
                if i >= n {
                    continue;
                }
                if cache[i].is_none() {
                    if evals >= cap {
                        continue;
                    }
                    evals += 1;
                    cache[i] = Some(score(i));
                }
                if let Some(Some(s)) = cache[i] {
                    if s > best_s {
                        best_i = i;
                        best_s = s;
                        improved = true;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        Some((best_i, evals, best_s))
    }

    /// Model-blind entry point: [`SearchStrategy::search_ranked`] with
    /// no pinned incumbents and no cost model.  Returns the chosen
    /// index, the number of evaluations spent, and the best score.
    fn search(
        &self,
        n_candidates: usize,
        score: &mut dyn FnMut(usize) -> Option<f64>,
    ) -> Option<(usize, usize, f64)> {
        self.search_ranked(n_candidates, &[], &|_| None, score)
    }
}

/// Evaluate every candidate (the paper's offline-tuning mode).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExhaustiveSearch;

impl SearchStrategy for ExhaustiveSearch {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn propose(
        &self,
        n: usize,
        _pinned: &[usize],
        _rank: &dyn Fn(usize) -> Option<f64>,
    ) -> Vec<usize> {
        (0..n).collect()
    }
}

/// Evaluate a random subset (cheap screening for huge spaces).
/// Deterministic for a given seed.
#[derive(Debug, Clone, Copy)]
pub struct RandomSearch {
    /// How many candidates to sample (capped at the space size).
    pub samples: usize,
    /// RNG seed; identical seeds reproduce the search exactly.
    pub seed: u64,
}

impl SearchStrategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn propose(
        &self,
        n: usize,
        pinned: &[usize],
        _rank: &dyn Fn(usize) -> Option<f64>,
    ) -> Vec<usize> {
        let mut out: Vec<usize> = Vec::new();
        for &i in pinned {
            if i < n && !out.contains(&i) {
                out.push(i);
            }
        }
        let mut state = self.seed | 1;
        for _ in 0..self.samples.min(n) {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let i = (state % n as u64) as usize;
            if !out.contains(&i) {
                out.push(i);
            }
        }
        out
    }
}

/// Random restarts + greedy neighbourhood walk; the "ML-ish" strategy the
/// paper leaves as future work, kept deterministic for reproducibility.
///
/// # Examples
///
/// ```
/// use portable_kernels::tuner::{HillClimb, SearchStrategy};
///
/// // Climb a simple unimodal score over 100 candidates.
/// let strategy = HillClimb { restarts: 4, seed: 7 };
/// let (best, evals, score) = strategy
///     .search(100, &mut |i| Some(-(i as f64 - 60.0).abs()))
///     .unwrap();
/// assert_eq!(best, 60);
/// assert_eq!(score, 0.0);
/// // ...in far fewer evaluations than the exhaustive 100.
/// assert!(evals < 100);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct HillClimb {
    /// Number of random restart points.
    pub restarts: usize,
    /// RNG seed; identical seeds reproduce the search exactly.
    pub seed: u64,
}

impl SearchStrategy for HillClimb {
    fn name(&self) -> &'static str {
        "hill"
    }

    fn propose(
        &self,
        n: usize,
        pinned: &[usize],
        _rank: &dyn Fn(usize) -> Option<f64>,
    ) -> Vec<usize> {
        let mut out: Vec<usize> = Vec::new();
        for &i in pinned {
            if i < n && !out.contains(&i) {
                out.push(i);
            }
        }
        let mut state = self.seed | 1;
        for _ in 0..self.restarts {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let i = (state % n as u64) as usize;
            if !out.contains(&i) {
                out.push(i);
            }
        }
        out
    }

    // Greedy walk over the index neighbourhood (candidate lists are
    // generated in lexicographic parameter order, so +-1 / +-3 are
    // parameter neighbours).
    fn refine(&self, best: usize, _n: usize) -> Vec<usize> {
        vec![
            best.wrapping_sub(1),
            best + 1,
            best + 3,
            best.wrapping_sub(3),
        ]
    }
}

/// Model-guided search: measure only the cost model's top-ranked
/// candidates (plus every pinned incumbent — the untuned default, the
/// stored winner, warm-start seeds), then hill-climb around the
/// measured winner, all under a hard measurement `budget`.
///
/// Unmodeled candidates (`rank` = `None`) are worst-ranked: they are
/// measured only after every modeled candidate, never dropped ahead of
/// one — conservative pruning.  Candidates whose predicted costs *tie*
/// keep grid order, so every variant along an unmodeled axis (ISA,
/// threads) of a tied blocking is proposed together.
///
/// # Examples
///
/// ```
/// use portable_kernels::tuner::{GuidedSearch, SearchStrategy};
///
/// // A 100-point space; the model correctly ranks index 60 cheapest,
/// // index 0 is the pinned untuned default.
/// let strategy = GuidedSearch { budget: 8 };
/// let (best, evals, score) = strategy
///     .search_ranked(
///         100,
///         &[0],
///         &|i| Some((i as f64 - 60.0).abs()),
///         &mut |i| Some(-(i as f64 - 60.0).abs()),
///     )
///     .unwrap();
/// assert_eq!(best, 60);
/// assert_eq!(score, 0.0);
/// // ...within the measurement budget, not the exhaustive 100.
/// assert!(evals <= 8);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct GuidedSearch {
    /// Hard cap on measured candidates per search (proposals +
    /// refinement).  Pinned incumbents are proposed first, so they are
    /// the last thing a small budget drops.
    pub budget: usize,
}

impl Default for GuidedSearch {
    fn default() -> Self {
        Self { budget: 8 }
    }
}

impl SearchStrategy for GuidedSearch {
    fn name(&self) -> &'static str {
        "guided"
    }

    fn max_evals(&self) -> Option<usize> {
        Some(self.budget.max(1))
    }

    fn propose(
        &self,
        n: usize,
        pinned: &[usize],
        rank: &dyn Fn(usize) -> Option<f64>,
    ) -> Vec<usize> {
        let budget = self.budget.max(1);
        let mut out: Vec<usize> = Vec::new();
        for &i in pinned {
            if i < n && !out.contains(&i) {
                out.push(i);
            }
        }
        // Rank the rest: modeled candidates ascending by predicted
        // cost (ties keep grid order), unmodeled candidates after every
        // modeled one (worst rank).
        let mut modeled: Vec<(f64, usize)> = Vec::new();
        let mut unmodeled: Vec<usize> = Vec::new();
        for i in 0..n {
            if out.contains(&i) {
                continue;
            }
            match rank(i) {
                Some(c) if c.is_finite() => modeled.push((c, i)),
                _ => unmodeled.push(i),
            }
        }
        modeled.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        // Keep ~a quarter of the budget for refinement around the
        // measured winner.
        let cap = out.len()
            + (budget.saturating_sub(out.len()) * 3 / 4).max(1);
        for i in modeled.into_iter().map(|(_, i)| i).chain(unmodeled) {
            if out.len() >= cap {
                break;
            }
            out.push(i);
        }
        out
    }

    fn refine(&self, best: usize, _n: usize) -> Vec<usize> {
        vec![
            best.wrapping_sub(1),
            best + 1,
            best + 3,
            best.wrapping_sub(3),
        ]
    }
}

/// Tune GEMM for a problem on a device using the analytic model.
pub fn tune_gemm(
    dev: &DeviceSpec,
    p: GemmProblem,
    strategy: &dyn SearchStrategy,
) -> Option<TuneResult<GemmConfig>> {
    let space = gemm_space();
    let mut infeasible = 0usize;
    let mut score = |i: usize| match gemm_estimate(dev, p, &space[i]) {
        Ok(e) => Some(e.gflops),
        Err(_) => {
            infeasible += 1;
            None
        }
    };
    let (idx, evaluated, gflops) = strategy.search(space.len(), &mut score)?;
    Some(TuneResult {
        config: space[idx],
        gflops,
        evaluated,
        infeasible,
    })
}

/// Tune a convolution layer on a device using the analytic model.
/// The GEMM configuration feeding im2col/Winograd is itself tuned first.
pub fn tune_conv(
    dev: &DeviceSpec,
    layer: &ConvLayer,
    batch: u32,
    strategy: &dyn SearchStrategy,
) -> Option<TuneResult<ConvConfig>> {
    let (gm, gn, gk) = layer.im2col_gemm(batch);
    let gemm_cfg = tune_gemm(dev, GemmProblem::new(gm, gn, gk), strategy)
        .map(|r| r.config)
        .unwrap_or_default();

    let space = conv_space(layer.window, layer.stride);
    let p = ConvProblem::new(layer.clone(), batch);
    let mut infeasible = 0usize;
    let mut score = |i: usize| match conv_estimate(dev, &p, &space[i], &gemm_cfg)
    {
        Ok(e) => Some(e.gflops),
        Err(_) => {
            infeasible += 1;
            None
        }
    };
    let (idx, evaluated, gflops) = strategy.search(space.len(), &mut score)?;
    Some(TuneResult {
        config: space[idx],
        gflops,
        evaluated,
        infeasible,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::device_by_name;

    #[test]
    fn exhaustive_finds_global_argmax() {
        let scores = [1.0, 5.0, 3.0, 5.5, 0.5];
        let mut f = |i: usize| Some(scores[i]);
        let (idx, evals, best) =
            ExhaustiveSearch.search(scores.len(), &mut f).unwrap();
        assert_eq!(idx, 3);
        assert_eq!(evals, 5);
        assert_eq!(best, 5.5);
    }

    #[test]
    fn exhaustive_skips_infeasible() {
        let mut f = |i: usize| if i == 2 { Some(1.0) } else { None };
        let (idx, _, _) = ExhaustiveSearch.search(5, &mut f).unwrap();
        assert_eq!(idx, 2);
        let mut none = |_: usize| None;
        assert!(ExhaustiveSearch.search(5, &mut none).is_none());
    }

    #[test]
    fn random_search_is_deterministic() {
        let mut f1 = |i: usize| Some(i as f64);
        let mut f2 = |i: usize| Some(i as f64);
        let s = RandomSearch { samples: 10, seed: 42 };
        assert_eq!(s.search(100, &mut f1), s.search(100, &mut f2));
    }

    #[test]
    fn hill_climb_never_worse_than_its_start_samples() {
        // On a smooth landscape it should land near the peak.
        let mut f = |i: usize| {
            let x = i as f64 / 99.0;
            Some(-(x - 0.7) * (x - 0.7))
        };
        let (idx, _, _) = HillClimb { restarts: 8, seed: 7 }
            .search(100, &mut f)
            .unwrap();
        assert!((idx as i64 - 70).abs() <= 5, "landed at {idx}");
    }

    #[test]
    fn driver_never_remeasures_a_candidate() {
        // Pinned, proposed, and refined indices overlap; the memoized
        // driver must still evaluate each index at most once.
        let mut measured: Vec<usize> = Vec::new();
        let strategy = HillClimb { restarts: 16, seed: 3 };
        let (_, evals, _) = strategy
            .search_ranked(20, &[0, 0, 5], &|_| None, &mut |i| {
                measured.push(i);
                Some(i as f64)
            })
            .unwrap();
        assert_eq!(evals, measured.len());
        let mut dedup = measured.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), measured.len(), "re-measured: {measured:?}");
    }

    #[test]
    fn guided_measures_pinned_before_ranked_candidates() {
        // Truthful model: cheapest-cost candidate is the true winner.
        let mut measured: Vec<usize> = Vec::new();
        let strategy = GuidedSearch { budget: 4 };
        let (best, evals, _) = strategy
            .search_ranked(
                10,
                &[7],
                &|i| Some(i as f64),
                &mut |i| {
                    measured.push(i);
                    Some(-(i as f64))
                },
            )
            .unwrap();
        // The pinned incumbent is the very first measurement, the
        // model's top pick follows, and the budget caps the rest.
        assert_eq!(measured[0], 7);
        assert_eq!(measured[1], 0);
        assert_eq!(best, 0);
        assert!(evals <= 4, "budget exceeded: {measured:?}");
    }

    #[test]
    fn guided_tied_ranks_keep_grid_order() {
        // Pairs of candidates tie on predicted cost (an unmodeled axis
        // such as ISA or threads): both variants of the best-ranked
        // pair must be proposed, in grid order, before the next pair.
        let strategy = GuidedSearch { budget: 16 };
        let proposals =
            strategy.propose(8, &[], &|i| Some((i / 2) as f64));
        assert_eq!(&proposals[..4], &[0, 1, 2, 3]);
    }

    #[test]
    fn guided_unmodeled_candidates_rank_worst_but_survive() {
        // Candidates 0..3 are unmodeled (None): they must come after
        // every modeled candidate, not be dropped, so pruning is
        // conservative.
        let strategy = GuidedSearch { budget: 32 };
        let proposals = strategy.propose(6, &[], &|i| {
            if i < 3 {
                None
            } else {
                Some(i as f64)
            }
        });
        assert_eq!(proposals, vec![3, 4, 5, 0, 1, 2]);
    }

    #[test]
    fn guided_with_lying_model_never_beats_the_pinned_default() {
        // The model inverts the truth (claims the worst candidate is
        // cheapest).  The pinned default is still measured, so the
        // returned winner can never score below it.
        let truth = |i: usize| Some(((i % 3) as f64) - (i as f64) / 10.0);
        let strategy = GuidedSearch { budget: 3 };
        let (_, _, best) = strategy
            .search_ranked(
                12,
                &[0],
                // Lying rank: pretends high indices are cheapest.
                &|i| Some(-(i as f64)),
                &mut |i| truth(i),
            )
            .unwrap();
        let default_score = truth(0).unwrap();
        assert!(best >= default_score, "{best} < default {default_score}");
    }

    #[test]
    fn guided_budget_one_measures_exactly_the_default() {
        let mut measured: Vec<usize> = Vec::new();
        let strategy = GuidedSearch { budget: 1 };
        let (best, evals, _) = strategy
            .search_ranked(10, &[0], &|i| Some(-(i as f64)), &mut |i| {
                measured.push(i);
                Some(i as f64)
            })
            .unwrap();
        assert_eq!((best, evals), (0, 1));
        assert_eq!(measured, vec![0]);
    }

    #[test]
    fn tune_gemm_beats_fixed_default() {
        let dev = device_by_name("mali-g71").unwrap();
        let p = GemmProblem::new(512, 512, 512);
        let tuned = tune_gemm(&dev, p, &ExhaustiveSearch).unwrap();
        let default = crate::perfmodel::gemm_estimate(
            &dev, p, &GemmConfig::default()
        ).unwrap();
        assert!(tuned.gflops >= default.gflops);
        assert!(tuned.evaluated > 100);
    }

    #[test]
    fn tuned_configs_differ_across_devices() {
        // The paper's core claim: different hardware picks different
        // parameters.  Tuned Mali (no local mem) and R9 Nano (big LDS)
        // winners should differ in at least one parameter.
        let p = GemmProblem::new(1024, 1024, 1024);
        let mali = tune_gemm(&device_by_name("mali-g71").unwrap(), p,
                             &ExhaustiveSearch).unwrap();
        let amd = tune_gemm(&device_by_name("r9-nano").unwrap(), p,
                            &ExhaustiveSearch).unwrap();
        assert_ne!(mali.config, amd.config,
                   "expected device-specific winners, both chose {}",
                   mali.config.name());
        // Mali must not stage through (emulated) local memory.
        assert!(!mali.config.use_local);
    }

    #[test]
    fn tune_conv_picks_winograd_for_heavy_3x3() {
        let dev = device_by_name("uhd630").unwrap();
        let layer = crate::nn::ConvLayer::same("t", 3, 1, 56, 56, 256, 256);
        let r = tune_conv(&dev, &layer, 4, &ExhaustiveSearch).unwrap();
        assert_eq!(
            r.config.algorithm,
            crate::config::ConvAlgorithm::Winograd,
            "picked {:?}", r.config
        );
    }

    #[test]
    fn tune_conv_never_picks_winograd_for_pointwise() {
        let dev = device_by_name("uhd630").unwrap();
        let layer = crate::nn::ConvLayer::same("t", 1, 1, 28, 28, 256, 512);
        let r = tune_conv(&dev, &layer, 4, &ExhaustiveSearch).unwrap();
        assert_ne!(r.config.algorithm, crate::config::ConvAlgorithm::Winograd);
    }
}
