//! Online re-tuning: epoch-swappable selection snapshots plus a
//! measured, never-worse promotion pass over live traffic.
//!
//! The offline story (`tune_device` → [`SelectionDb`] → serve) leaves a
//! serving fleet frozen at whatever mix it was tuned for.  This module
//! closes the loop while requests keep flowing:
//!
//! * [`TuningHandle`] — a copy-on-write, epoch-stamped holder of the
//!   shared [`SelectionDb`].  Readers take a [`TuningSnapshot`] (one
//!   mutex-guarded `Arc` clone — no DB copy); a writer builds the next
//!   DB off to the side and swaps it in atomically with
//!   [`TuningHandle::publish_from`], bumping the epoch.  Readers never
//!   see a torn view: epoch and DB travel together in one snapshot.
//! * [`retune_pass`] — one targeted re-tune: probe only the hot shape
//!   classes via [`tune_space_sweep_filtered`] under a *guided* search
//!   ([`super::GuidedSearch`] — model-ranked candidates plus the pinned
//!   incumbent, capped at [`RetuneConfig::budget`] measured points per
//!   class, so a pass costs a handful of probes instead of a grid), then
//!   *verify* every would-be winner head-to-head against the incumbent
//!   point in the same probe session.  A candidate that does not measure
//!   strictly faster than the incumbent is dropped — the promotion path
//!   never installs a point that measured worse (see
//!   `docs/TUNING.md#online-re-tuning`).
//! * [`OnlineTuner`] — the background task: a dedicated native probe
//!   engine re-tunes on an interval, and every published snapshot is
//!   handed to a callback (the serving side installs it with
//!   `EnginePool::swap_tuning`, which invalidates only the plans whose
//!   selection actually changed).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::blas::Isa;
use crate::config::{ConvPoint, GemmPoint, KernelSpace};
use crate::error::{Error, Result};
use crate::runtime::{ArtifactStore, Backend, NativeEngine, HOST_DEVICE};

use super::db::{SelectionDb, SelectionKey};
use super::host::{
    conv_native_grid, gemm_point_grid, shape_class_for, tune_space_sweep_filtered,
};
use super::search::GuidedSearch;

/// An immutable, epoch-stamped view of the selection database.  Cheap to
/// clone (an `Arc` bump); everything planned against one snapshot sees
/// one consistent set of selections.
#[derive(Debug, Clone)]
pub struct TuningSnapshot {
    /// Publish counter: 0 for the seed DB, +1 per successful publish.
    pub epoch: u64,
    /// The selections as of this epoch.
    pub db: Arc<SelectionDb>,
}

/// Copy-on-write, epoch-swappable holder of the shared [`SelectionDb`].
///
/// The serving side reads ([`TuningHandle::snapshot`]) on every plan; a
/// single re-tuner writes.  The epoch makes the swap protocol checkable:
/// a snapshot's `db` always matches its `epoch`, and
/// [`TuningHandle::publish_from`] refuses to install a DB built from a
/// stale base, so two racing writers cannot silently clobber each
/// other's promotions.
#[derive(Debug)]
pub struct TuningHandle {
    current: Mutex<TuningSnapshot>,
}

impl TuningHandle {
    /// Wrap a seed database at epoch 0.
    pub fn new(db: SelectionDb) -> Self {
        Self {
            current: Mutex::new(TuningSnapshot { epoch: 0, db: Arc::new(db) }),
        }
    }

    /// The current snapshot (epoch + DB, consistent as a pair).
    pub fn snapshot(&self) -> TuningSnapshot {
        self.current.lock().expect("tuning handle lock poisoned").clone()
    }

    /// The current epoch.
    pub fn epoch(&self) -> u64 {
        self.snapshot().epoch
    }

    /// Unconditionally install `next` as the new current DB, bumping the
    /// epoch.  Returns the snapshot just published.
    pub fn publish(&self, next: SelectionDb) -> TuningSnapshot {
        let mut cur = self.current.lock().expect("tuning handle lock poisoned");
        *cur = TuningSnapshot { epoch: cur.epoch + 1, db: Arc::new(next) };
        cur.clone()
    }

    /// Install `next` only if the current epoch still equals
    /// `base.epoch` — the compare-and-swap rung of the promotion
    /// protocol.  `Ok` carries the published snapshot; `Err` returns the
    /// snapshot that won the race so the caller can rebase and retry (or
    /// drop its pass).
    pub fn publish_from(
        &self,
        base: &TuningSnapshot,
        next: SelectionDb,
    ) -> std::result::Result<TuningSnapshot, TuningSnapshot> {
        let mut cur = self.current.lock().expect("tuning handle lock poisoned");
        if cur.epoch != base.epoch {
            return Err(cur.clone());
        }
        *cur = TuningSnapshot { epoch: cur.epoch + 1, db: Arc::new(next) };
        Ok(cur.clone())
    }
}

/// Knobs for one re-tune pass.
#[derive(Debug, Clone)]
pub struct RetuneConfig {
    /// Timed repetitions per probe (minimum taken).
    pub iters: usize,
    /// Use the quick candidate grids (the CI smoke shape).
    pub quick: bool,
    /// Device namespace selections are keyed under.
    pub device: String,
    /// `threads` axis the probe grids cross (0 = auto).
    pub threads: Vec<usize>,
    /// Measured-point budget per hot shape class: the explore step runs
    /// [`GuidedSearch`] with this budget, so a pass probes the model's
    /// top candidates plus the incumbent instead of the whole grid.
    pub budget: usize,
}

impl Default for RetuneConfig {
    fn default() -> Self {
        Self {
            iters: 3,
            quick: true,
            device: HOST_DEVICE.to_string(),
            threads: vec![1, 0],
            budget: 8,
        }
    }
}

/// One verified promotion: the candidate measured strictly faster than
/// the incumbent in the same probe session.
#[derive(Debug, Clone)]
pub struct Promotion {
    /// Problem-class key the new point was installed under.
    pub key: SelectionKey,
    /// Compact name of the promoted point.
    pub point: String,
    /// Incumbent's throughput in the verification probe, GFLOP/s.
    pub incumbent_gflops: f64,
    /// Candidate's throughput in the verification probe, GFLOP/s.
    pub candidate_gflops: f64,
}

/// Outcome of one [`retune_pass`].
#[derive(Debug, Clone, Default)]
pub struct RetunePass {
    /// Epoch published by this pass (`None` when nothing was promoted).
    pub epoch: Option<u64>,
    /// Every verified promotion installed into the published DB.
    pub promoted: Vec<Promotion>,
    /// Sweep winners that *lost* their verification probe (incumbent
    /// left untouched).
    pub rejected: usize,
    /// Artifacts the targeted sweep actually probed.
    pub probed: usize,
}

/// Head-to-head verification: measure `candidate` and the incumbent (or
/// the space default when nothing is stored) on the same artifact in the
/// same session, and install the candidate into `next` only if it
/// measured strictly faster *and* finite.  This is the invariant the
/// whole promotion path hangs off: no code path writes into the
/// published DB except through this guard.
#[allow(clippy::too_many_arguments)]
fn verify_and_promote<B: Backend, P: KernelSpace>(
    engine: &mut B,
    snap_db: &SelectionDb,
    next: &mut SelectionDb,
    pass: &mut RetunePass,
    device: &str,
    iters: usize,
    op: &str,
    artifact: &str,
    candidate: P,
    apply: &mut dyn FnMut(&mut B, &P),
) -> Result<()> {
    let key =
        SelectionKey { device: device.to_string(), op: op.to_string() };
    let flops = engine.store().get(artifact)?.flops;
    let inputs = engine.synth_inputs(artifact, 17)?;
    let mut measure = |engine: &mut B, p: &P| -> Result<f64> {
        apply(engine, p);
        let (out, _) = engine.run_timed(artifact, &inputs, iters)?;
        Ok(out.gflops(flops))
    };
    let incumbent_point = match snap_db.get::<P>(&key) {
        Some((p, _stored_gflops)) => {
            if p == candidate {
                // Already the selection; nothing to promote.
                return Ok(());
            }
            p
        }
        // No stored selection: the effective incumbent is the engine
        // default, so the candidate must beat that to earn an entry.
        None => P::default_point(),
    };
    let candidate_gflops = measure(engine, &candidate)?;
    let incumbent_gflops = measure(engine, &incumbent_point)?;
    if candidate_gflops.is_finite()
        && candidate_gflops > 0.0
        && candidate_gflops > incumbent_gflops
    {
        next.put(key.clone(), candidate, candidate_gflops);
        pass.promoted.push(Promotion {
            key,
            point: candidate.point_name(),
            incumbent_gflops,
            candidate_gflops,
        });
    } else {
        pass.rejected += 1;
    }
    Ok(())
}

/// One targeted re-tune pass over the hot shape classes.
///
/// Protocol (single writer; concurrent passes are rejected loudly):
///
/// 1. snapshot the current DB (epoch `E`);
/// 2. *explore*: run [`tune_space_sweep_filtered`] with a
///    [`GuidedSearch`] capped at [`RetuneConfig::budget`] probes per
///    class over the artifacts whose [`shape_class_for`] label is in
///    `hot`, against a scratch clone of the snapshot — the stored
///    incumbent is pinned into the probe set, and the sweep's own
///    incumbent guard keeps only candidates that beat the stored
///    numbers;
/// 3. *verify*: re-measure every sweep winner head-to-head against the
///    incumbent point in this same session; only strictly-faster,
///    finite winners are written into the next DB;
/// 4. publish the next DB from base epoch `E`
///    ([`TuningHandle::publish_from`]), so a lost race surfaces as an
///    error instead of clobbering another writer's promotions.
///
/// The probe `engine` must resolve plans from its *fallback* point
/// (e.g. `NativeEngine::new` over a store clone): an engine with a
/// tuning DB attached would ignore `apply_*` and every probe would time
/// the same kernel.  `hot` holds shape-class labels
/// (`gemm_128x128x128`, ...), exactly the latency-accounting keys the
/// serving side reports.
#[allow(clippy::too_many_arguments)]
pub fn retune_pass<B: Backend>(
    engine: &mut B,
    handle: &TuningHandle,
    hot: &[String],
    cfg: &RetuneConfig,
    apply_gemm: &mut dyn FnMut(&mut B, &GemmPoint),
    apply_conv: &mut dyn FnMut(&mut B, &ConvPoint),
) -> Result<RetunePass> {
    let snap = handle.snapshot();
    let mut pass = RetunePass::default();
    if hot.is_empty() {
        return Ok(pass);
    }
    let is_hot = |meta: &crate::runtime::ArtifactMeta| {
        shape_class_for(meta)
            .map(|c| hot.iter().any(|h| *h == c))
            .unwrap_or(false)
    };

    // Explore: targeted *guided* sweeps against a scratch DB (never
    // published).  The guided strategy pins the stored incumbent and
    // spends the per-class budget on the cost model's top candidates.
    let mut scratch = (*snap.db).clone();
    let guided = GuidedSearch { budget: cfg.budget };
    let isas = Isa::detect();
    let gemm_grid = gemm_point_grid(cfg.quick, &cfg.threads, &isas);
    let gemm_sweep = tune_space_sweep_filtered::<B, GemmPoint>(
        engine,
        "gemm",
        &gemm_grid,
        cfg.iters,
        &cfg.device,
        &guided,
        apply_gemm,
        &mut scratch,
        &is_hot,
    )?;
    let conv_grid = conv_native_grid(cfg.quick, &cfg.threads, &isas);
    let conv_sweep = tune_space_sweep_filtered::<B, ConvPoint>(
        engine,
        "conv",
        &conv_grid,
        cfg.iters,
        &cfg.device,
        &guided,
        apply_conv,
        &mut scratch,
        &is_hot,
    )?;
    let mut probed: Vec<&str> = Vec::new();
    for artifact in gemm_sweep
        .rows
        .iter()
        .map(|r| r.artifact.as_str())
        .chain(conv_sweep.rows.iter().map(|r| r.artifact.as_str()))
    {
        if !probed.contains(&artifact) {
            probed.push(artifact);
        }
    }
    pass.probed = probed.len();

    // Verify: candidates earn their slot head-to-head or not at all.
    let mut next = (*snap.db).clone();
    for (op, (candidate, _swept)) in &gemm_sweep.winners {
        let Some(row) = gemm_sweep.rows.iter().find(|r| r.problem == *op)
        else {
            continue;
        };
        let artifact = row.artifact.clone();
        verify_and_promote(
            engine,
            &snap.db,
            &mut next,
            &mut pass,
            &cfg.device,
            cfg.iters,
            op,
            &artifact,
            *candidate,
            apply_gemm,
        )?;
    }
    for (op, (candidate, _swept)) in &conv_sweep.winners {
        let Some(row) = conv_sweep.rows.iter().find(|r| r.problem == *op)
        else {
            continue;
        };
        let artifact = row.artifact.clone();
        verify_and_promote(
            engine,
            &snap.db,
            &mut next,
            &mut pass,
            &cfg.device,
            cfg.iters,
            op,
            &artifact,
            *candidate,
            apply_conv,
        )?;
    }

    if pass.promoted.is_empty() {
        return Ok(pass);
    }
    match handle.publish_from(&snap, next) {
        Ok(published) => {
            pass.epoch = Some(published.epoch);
            Ok(pass)
        }
        Err(winner) => Err(Error::Runtime(format!(
            "online re-tune raced another writer: pass built from epoch \
             {} but epoch {} was published meanwhile — re-tuning is \
             single-writer, rebase and retry",
            snap.epoch, winner.epoch
        ))),
    }
}

/// [`retune_pass`] specialized to a native probe engine (the applies are
/// `set_gemm_point` / `set_conv_point`; each re-plans on the next run).
pub fn retune_native(
    engine: &mut NativeEngine,
    handle: &TuningHandle,
    hot: &[String],
    cfg: &RetuneConfig,
) -> Result<RetunePass> {
    retune_pass(
        engine,
        handle,
        hot,
        cfg,
        &mut |e, p| e.set_gemm_point(*p),
        &mut |e, p| e.set_conv_point(*p),
    )
}

/// Granularity of the background tuner's interruptible sleep.
const STOP_POLL: Duration = Duration::from_millis(10);

/// The background re-tuner task: a dedicated native probe engine runs
/// [`retune_native`] every `interval`, targeting whatever shape classes
/// the `hot` provider reports (typically
/// `EngineStats::hot_shape_classes` from the serving pool), and hands
/// every *published* snapshot to `on_publish` so the serving side can
/// install it (`EnginePool::swap_tuning`).
///
/// Dropping (or [`OnlineTuner::stop`]-ping) the handle stops the thread
/// and joins it; a pass in flight finishes first.
pub struct OnlineTuner {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
    passes: Arc<AtomicUsize>,
}

impl OnlineTuner {
    /// Spawn the background task.  The probe engine is constructed here
    /// (synchronously, so store problems fail loudly) and moved onto the
    /// tuner thread.
    pub fn spawn<H, C>(
        store: ArtifactStore,
        handle: Arc<TuningHandle>,
        cfg: RetuneConfig,
        interval: Duration,
        mut hot: H,
        mut on_publish: C,
    ) -> Result<Self>
    where
        H: FnMut() -> Vec<String> + Send + 'static,
        C: FnMut(&TuningSnapshot, &RetunePass) + Send + 'static,
    {
        let mut engine = NativeEngine::new(store)?;
        let stop = Arc::new(AtomicBool::new(false));
        let passes = Arc::new(AtomicUsize::new(0));
        let stop_t = Arc::clone(&stop);
        let passes_t = Arc::clone(&passes);
        let join = std::thread::Builder::new()
            .name("online-tuner".into())
            .spawn(move || {
                while !stop_t.load(Ordering::Acquire) {
                    let classes = hot();
                    if !classes.is_empty() {
                        if let Ok(pass) =
                            retune_native(&mut engine, &handle, &classes, &cfg)
                        {
                            passes_t.fetch_add(1, Ordering::Relaxed);
                            if pass.epoch.is_some() {
                                on_publish(&handle.snapshot(), &pass);
                            }
                        }
                    }
                    let t0 = Instant::now();
                    while !stop_t.load(Ordering::Acquire)
                        && t0.elapsed() < interval
                    {
                        std::thread::sleep(STOP_POLL.min(interval));
                    }
                }
            })
            .map_err(|e| {
                Error::Runtime(format!("cannot spawn online tuner thread: {e}"))
            })?;
        Ok(Self { stop, join: Some(join), passes })
    }

    /// Completed re-tune passes so far (published or not).
    pub fn passes(&self) -> usize {
        self.passes.load(Ordering::Relaxed)
    }

    /// Stop the background thread and join it.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for OnlineTuner {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::BlockedParams;
    use crate::util::tmp::TempDir;

    fn fixture_store(prefix: &str) -> (TempDir, ArtifactStore) {
        let dir = TempDir::new(prefix).unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "artifacts": [{
                "name": "g96", "kind": "gemm", "impl": "pallas",
                "file": "g96.hlo.txt", "flops": 1769472,
                "m": 96, "n": 96, "k": 96,
                "inputs": [{"shape": [96, 96], "dtype": "float32"},
                           {"shape": [96, 96], "dtype": "float32"}],
                "groups": ["gemm"]}]}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        (dir, store)
    }

    #[test]
    fn snapshot_epoch_and_db_travel_together() {
        let handle = TuningHandle::new(SelectionDb::new());
        let s0 = handle.snapshot();
        assert_eq!(s0.epoch, 0);
        assert!(s0.db.is_empty());

        let mut next = (*s0.db).clone();
        next.put(
            SelectionKey::gemm(HOST_DEVICE, 96, 96, 96),
            GemmPoint::default(),
            1.0,
        );
        let s1 = handle.publish(next);
        assert_eq!(s1.epoch, 1);
        assert_eq!(s1.db.len(), 1);
        // The old snapshot is immutable: published changes never reach it.
        assert!(s0.db.is_empty());
        assert_eq!(handle.epoch(), 1);
    }

    #[test]
    fn publish_from_rejects_stale_base() {
        let handle = TuningHandle::new(SelectionDb::new());
        let base = handle.snapshot();
        handle.publish(SelectionDb::new()); // epoch 1 wins the race
        let lost = handle.publish_from(&base, SelectionDb::new());
        let winner = lost.err().expect("stale base must be rejected");
        assert_eq!(winner.epoch, 1);
        assert_eq!(handle.epoch(), 1, "stale publish must not bump epoch");
    }

    #[test]
    fn retune_promotes_over_a_poisoned_incumbent() {
        let (_dir, store) = fixture_store("online-promote");
        // Seed: a deliberately terrible point (tiny tiles, heavy
        // oversubscription) stored as the incumbent for g96.
        let mut seed = SelectionDb::new();
        let poisoned = GemmPoint::scalar(BlockedParams {
            bm: 8,
            bn: 8,
            bk: 8,
            mr: 2,
            nr: 2,
            threads: 8,
        });
        seed.put(
            SelectionKey::gemm(HOST_DEVICE, 96, 96, 96),
            poisoned,
            0.01,
        );
        let handle = TuningHandle::new(seed);
        let mut probe = NativeEngine::new(store).unwrap();
        let hot = vec!["gemm_128x128x128".to_string()];
        let cfg = RetuneConfig { iters: 1, ..Default::default() };
        let pass = retune_native(&mut probe, &handle, &hot, &cfg).unwrap();
        assert!(pass.probed >= 1, "g96 must be probed: {pass:?}");
        // Whether promotion happened depends on real timing, but the
        // invariant is checkable: every promotion measured strictly
        // faster than its incumbent, and a publish implies promotions.
        for p in &pass.promoted {
            assert!(
                p.candidate_gflops > p.incumbent_gflops,
                "never-worse violated: {p:?}"
            );
            assert!(p.candidate_gflops.is_finite());
        }
        match pass.epoch {
            Some(e) => {
                assert!(!pass.promoted.is_empty());
                assert_eq!(handle.epoch(), e);
            }
            None => assert!(pass.promoted.is_empty()),
        }
    }

    #[test]
    fn retune_with_no_hot_classes_is_a_no_op() {
        let (_dir, store) = fixture_store("online-noop");
        let handle = TuningHandle::new(SelectionDb::new());
        let mut probe = NativeEngine::new(store).unwrap();
        let pass = retune_native(
            &mut probe,
            &handle,
            &[],
            &RetuneConfig::default(),
        )
        .unwrap();
        assert_eq!(pass.probed, 0);
        assert!(pass.promoted.is_empty());
        assert_eq!(handle.epoch(), 0);
    }

    #[test]
    fn background_tuner_stops_cleanly() {
        let (_dir, store) = fixture_store("online-bg");
        let handle = Arc::new(TuningHandle::new(SelectionDb::new()));
        let tuner = OnlineTuner::spawn(
            store,
            Arc::clone(&handle),
            RetuneConfig { iters: 1, ..Default::default() },
            Duration::from_millis(5),
            || vec!["gemm_128x128x128".to_string()],
            |_snap, _pass| {},
        )
        .unwrap();
        // Give it a chance to run at least one pass, then stop.
        let t0 = Instant::now();
        while tuner.passes() == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(tuner.passes() >= 1, "background tuner never ran a pass");
        tuner.stop();
    }
}
