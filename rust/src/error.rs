//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — the offline build has no
//! `thiserror`, and the surface is small enough that the derive buys
//! nothing.

use std::fmt;

/// Errors produced by the portable-kernels library.
#[derive(Debug)]
pub enum Error {
    /// A configuration string or parameter set failed validation.
    Config(String),

    /// A kernel configuration cannot run on the given device (e.g. its
    /// local-memory tile exceeds the device's local memory).
    Infeasible {
        /// Device the configuration was rejected for.
        device: String,
        /// Which constraint failed.
        reason: String,
    },

    /// Artifact manifest or HLO file problems.
    Artifact(String),

    /// Execution-backend failure (native dispatch or PJRT/XLA).
    Runtime(String),

    /// Unknown device, layer, or artifact name.
    NotFound(String),

    /// Underlying filesystem failure.
    Io(std::io::Error),

    /// Malformed JSON (manifest or selection DB).
    Json(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "invalid configuration: {m}"),
            Error::Infeasible { device, reason } => {
                write!(f, "configuration infeasible on {device}: {reason}")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::NotFound(m) => write!(f, "not found: {m}"),
            Error::Io(e) => write!(f, "i/o error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        assert_eq!(
            Error::Config("bad".into()).to_string(),
            "invalid configuration: bad"
        );
        assert_eq!(
            Error::Infeasible { device: "mali-g71".into(), reason: "lds".into() }
                .to_string(),
            "configuration infeasible on mali-g71: lds"
        );
        assert!(Error::NotFound("x".into()).to_string().contains("not found"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let err: Error = io.into();
        assert!(err.to_string().contains("gone"));
        use std::error::Error as _;
        assert!(err.source().is_some());
    }
}
