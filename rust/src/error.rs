//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the portable-kernels library.
#[derive(Error, Debug)]
pub enum Error {
    /// A configuration string or parameter set failed validation.
    #[error("invalid configuration: {0}")]
    Config(String),

    /// A kernel configuration cannot run on the given device (e.g. its
    /// local-memory tile exceeds the device's local memory).
    #[error("configuration infeasible on {device}: {reason}")]
    Infeasible { device: String, reason: String },

    /// Artifact manifest or HLO file problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT/XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Unknown device, layer, or artifact name.
    #[error("not found: {0}")]
    NotFound(String),

    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
