//! Evaluation harness: regenerate every table and figure of the paper.
//!
//! Each generator returns a [`report::Report`] (rows + rendered text) and
//! can emit CSV; the `repro figures` CLI and the criterion benches drive
//! them.  Figure numbering follows the paper:
//!
//! | id | generator | paper content |
//! |----|-----------|---------------|
//! | t1 | [`tables::table1`] | device metrics |
//! | t2 | [`tables::table2`] | GEMM configurations |
//! | t3/t4 | [`tables::table3`], [`tables::table4`] | VGG/ResNet layers |
//! | f2 | [`fig_registers::fig2`] | conv register usage |
//! | f3 | [`fig_conv::fig3`] | conv tile/vector sweep on R9 Nano |
//! | f4a-c | [`fig_gemm::fig4`] | GEMM roofline on Intel UHD 630 |
//! | f5a-d | [`fig_gemm::fig5`] | GEMM roofline on Mali G-71 |
//! | f6-f9 | [`fig_network::fig_network`] | per-layer network gigaflops |

pub mod fig_conv;
pub mod fig_gemm;
pub mod fig_network;
pub mod fig_registers;
pub mod plot;
pub mod report;
pub mod sweep;
pub mod tables;

pub use report::Report;
