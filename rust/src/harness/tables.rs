//! Paper Tables 1-4 as reports.

use crate::config::GemmConfig;
use crate::device::all_devices;
use crate::nn::{resnet50_layers, vgg16_layers, ConvLayer};

use super::report::Report;

/// Table 1: performance metrics of the device zoo.
pub fn table1() -> Report {
    let mut r = Report::new(
        "Table 1: device performance metrics",
        &["device", "cache line", "local memory", "compute units"],
    );
    for d in all_devices().iter().take(6) {
        // The first six presets are the paper's Table-1 rows, in order.
        r.row(vec![
            d.name.clone(),
            format!("{} bytes", d.cache_line_bytes),
            if d.local_mem_bytes == 0 {
                "None".into()
            } else {
                format!("{} KiB", d.local_mem_bytes / 1024)
            },
            d.compute_units.to_string(),
        ]);
    }
    r
}

/// Table 2: the seven SYCL-BLAS configurations.
pub fn table2() -> Report {
    let mut r = Report::new(
        "Table 2: SYCL-BLAS GEMM configurations",
        &["configuration", "registers", "work group", "local mem"],
    );
    for cfg in GemmConfig::table2() {
        let lm = cfg.local_mem_bytes(32);
        r.row(vec![
            cfg.name(),
            cfg.registers().to_string(),
            cfg.work_group().to_string(),
            if lm == 0 { "N/A".into() } else { format!("{} KiB", lm / 1024) },
        ]);
    }
    r.note("local mem with X = 32 staging elements (see configs.py)");
    r
}

fn layer_table(title: &str, layers: &[ConvLayer]) -> Report {
    let mut r = Report::new(
        title,
        &["layer", "W", "S", "input", "output", "GFLOP(b=1)"],
    );
    for l in layers {
        r.row(vec![
            l.name.clone(),
            l.window.to_string(),
            l.stride.to_string(),
            format!("{}x{}x{}", l.in_h, l.in_w, l.in_c),
            format!("{}x{}x{}", l.out_h(), l.out_w(), l.out_c),
            format!("{:.3}", l.flops(1) as f64 / 1e9),
        ]);
    }
    r
}

/// Table 3: VGG-16 convolution layers.
pub fn table3() -> Report {
    layer_table("Table 3: VGG convolution layers", &vgg16_layers())
}

/// Table 4: ResNet-50 convolution layers.
pub fn table4() -> Report {
    layer_table("Table 4: ResNet convolution layers", &resnet50_layers())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_rows() {
        let r = table1();
        assert_eq!(r.rows.len(), 6);
        let text = r.render();
        assert!(text.contains("ARM Mali G71 GPU"));
        assert!(text.contains("447 KiB"));
        assert!(text.contains("128 bytes"));
    }

    #[test]
    fn table2_columns_match_paper() {
        let r = table2();
        assert_eq!(r.rows.len(), 7);
        let csv = r.to_csv();
        assert!(csv.contains("8x4_8x16_loc,32,128,16 KiB"));
        assert!(csv.contains("4x4_8x8_loc,16,64,8 KiB"));
        assert!(csv.contains("8x4_4x8_noloc,32,32,N/A"));
    }

    #[test]
    fn layer_tables_sizes() {
        assert_eq!(table3().rows.len(), 9);
        assert_eq!(table4().rows.len(), 26);
    }
}
