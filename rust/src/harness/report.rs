//! Tabular report type: render as aligned text or CSV.

use std::path::Path;

use crate::error::Result;

/// A rectangular report: header + rows of strings, with a title and
/// free-text notes (assumptions, paper expectations).
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Report title (also drives the CSV file slug).
    pub title: String,
    /// Column headers.
    pub columns: Vec<String>,
    /// Data rows; every row is as wide as `columns`.
    pub rows: Vec<Vec<String>>,
    /// Free-text notes rendered under the table.
    pub notes: Vec<String>,
}

impl Report {
    /// An empty report with a title and column headers.
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a data row (must match the column count); chainable.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.columns.len(),
            "row width mismatch in {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Append a free-text note; chainable.
    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        for note in &self.notes {
            out.push_str(&format!("note: {note}\n"));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.columns.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to other reports.
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Format a gflops value compactly.
pub fn gf(x: f64) -> String {
    if x >= 1000.0 {
        format!("{:.2} TF", x / 1000.0)
    } else if x >= 10.0 {
        format!("{x:.0} GF")
    } else {
        format!("{x:.2} GF")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut r = Report::new("demo", &["a", "bb"]);
        r.row(vec!["1".into(), "x,y".into()]);
        r.note("hello");
        let text = r.render();
        assert!(text.contains("== demo =="));
        assert!(text.contains("note: hello"));
        let csv = r.to_csv();
        assert_eq!(csv, "a,bb\n1,\"x,y\"\n");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        Report::new("demo", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn gf_formatting() {
        assert_eq!(gf(2570.0), "2.57 TF");
        assert_eq!(gf(290.0), "290 GF");
        assert_eq!(gf(0.05), "0.05 GF");
    }
}
