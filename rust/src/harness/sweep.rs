//! Roofline sweeps: the (M, N, K) grid of paper §5.2 evaluated through
//! the performance model.


use crate::config::GemmConfig;
use crate::device::DeviceSpec;
use crate::perfmodel::{gemm_estimate, GemmProblem};

/// One point of a roofline plot.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    /// GEMM M dimension.
    pub m: u64,
    /// GEMM N dimension.
    pub n: u64,
    /// GEMM K dimension.
    pub k: u64,
    /// flop/byte — the x-axis.
    pub intensity: f64,
    /// GFLOP/s — the y-axis.
    pub gflops: f64,
    /// Kernel configuration the point was modeled with.
    pub config: String,
    /// Whether the configuration is feasible on the device.
    pub feasible: bool,
}

/// The paper's §5.2 size grid: M, N, K powers of two in [64, 1024].
pub fn paper_size_grid() -> Vec<(u64, u64, u64)> {
    let sizes = [64u64, 128, 256, 512, 1024];
    let mut out = Vec::with_capacity(sizes.len().pow(3));
    for &m in &sizes {
        for &n in &sizes {
            for &k in &sizes {
                out.push((m, n, k));
            }
        }
    }
    out
}

/// Sweep one configuration over the full size grid on one device.
pub fn gemm_sweep(dev: &DeviceSpec, cfg: &GemmConfig) -> Vec<RooflinePoint> {
    paper_size_grid()
        .into_iter()
        .map(|(m, n, k)| {
            let p = GemmProblem::new(m, n, k);
            match gemm_estimate(dev, p, cfg) {
                Ok(e) => RooflinePoint {
                    m,
                    n,
                    k,
                    intensity: e.intensity,
                    gflops: e.gflops,
                    config: cfg.name(),
                    feasible: true,
                },
                Err(_) => RooflinePoint {
                    m,
                    n,
                    k,
                    intensity: p.intensity(),
                    gflops: 0.0,
                    config: cfg.name(),
                    feasible: false,
                },
            }
        })
        .collect()
}

/// For every grid point, which configuration wins (the "choose the best
/// combination" tuning step) — the data behind Fig. 5's A/B/C regions.
pub fn winners_per_point(
    dev: &DeviceSpec,
    cfgs: &[GemmConfig],
) -> Vec<(u64, u64, u64, String, f64)> {
    paper_size_grid()
        .into_iter()
        .map(|(m, n, k)| {
            let p = GemmProblem::new(m, n, k);
            let mut best: Option<(String, f64)> = None;
            for cfg in cfgs {
                if let Ok(e) = gemm_estimate(dev, p, cfg) {
                    if best.as_ref().map(|(_, g)| e.gflops > *g).unwrap_or(true)
                    {
                        best = Some((cfg.name(), e.gflops));
                    }
                }
            }
            let (name, g) = best.unwrap_or(("<none>".into(), 0.0));
            (m, n, k, name, g)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::device_by_name;

    #[test]
    fn grid_is_125_points() {
        assert_eq!(paper_size_grid().len(), 125);
    }

    #[test]
    fn sweep_covers_grid_and_stays_under_roofline() {
        let dev = device_by_name("uhd630").unwrap();
        let cfg = GemmConfig::parse("8x4_8x16_loc").unwrap();
        let pts = gemm_sweep(&dev, &cfg);
        assert_eq!(pts.len(), 125);
        for p in &pts {
            if p.feasible {
                assert!(p.gflops <= dev.roofline_gflops(p.intensity) + 1e-9);
            }
        }
    }

    #[test]
    fn winners_exist_everywhere_for_table2() {
        let dev = device_by_name("mali-g71").unwrap();
        for (_, _, _, name, g) in
            winners_per_point(&dev, &GemmConfig::table2())
        {
            assert_ne!(name, "<none>");
            assert!(g > 0.0);
        }
    }
}
