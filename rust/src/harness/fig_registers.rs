//! Figure 2: register usage of the tiled 3x3 convolution kernel across
//! tile and vector sizes (the paper's CodeXL measurements, modeled).

use crate::config::ConvConfig;
use crate::perfmodel::conv_regs;

use super::report::Report;

/// The sweep axes the paper's subplots use.
pub const TILES: [(u32, u32); 9] =
    [(1, 1), (1, 2), (2, 2), (2, 3), (3, 3), (3, 4), (4, 4), (4, 5), (5, 5)];
/// The vector widths the paper's subplots sweep.
pub const VECS: [u32; 3] = [1, 2, 4];

/// Generate Figure 2's data: registers per (tile, vec_c, vec_k).
pub fn fig2() -> Report {
    let mut r = Report::new(
        "Figure 2: registers used by the tiled 3x3 convolution kernel",
        &["tile", "vec_c", "vec_k", "registers", "spills@256"],
    );
    for (th, tw) in TILES {
        for vc in VECS {
            for vk in VECS {
                let regs = conv_regs(&ConvConfig::tiled(th, tw, vc, vk), 3);
                r.row(vec![
                    format!("{th}x{tw}"),
                    vc.to_string(),
                    vk.to_string(),
                    regs.to_string(),
                    if regs > 256 { "yes" } else { "no" }.into(),
                ]);
            }
        }
    }
    r.note("model: accumulators + halo patch + filter slice + addressing");
    r.note("paper reference: AMD CodeXL VGPR counts, 256-register budget");
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shape() {
        let r = fig2();
        assert_eq!(r.rows.len(), TILES.len() * VECS.len() * VECS.len());
    }

    #[test]
    fn fig2_monotone_along_each_axis() {
        // Fixing vectors, register usage grows with tile area.
        let at = |th: u32, tw: u32, vc: u32, vk: u32| {
            conv_regs(&ConvConfig::tiled(th, tw, vc, vk), 3)
        };
        assert!(at(1, 1, 1, 1) < at(2, 2, 1, 1));
        assert!(at(2, 2, 1, 1) < at(4, 4, 1, 1));
        assert!(at(4, 4, 1, 1) < at(4, 4, 2, 1));
        assert!(at(4, 4, 2, 1) < at(4, 4, 4, 1));
        assert!(at(4, 4, 4, 1) < at(4, 4, 4, 4));
    }

    #[test]
    fn fig2_spill_region_is_top_right() {
        // Only large-tile large-vector corners exceed the GCN budget.
        let r = fig2();
        let spills: Vec<_> = r
            .rows
            .iter()
            .filter(|row| row[4] == "yes")
            .map(|row| row[0].clone())
            .collect();
        assert!(!spills.is_empty());
        assert!(spills.iter().all(|t| {
            let (a, b) = t.split_once('x').unwrap();
            a.parse::<u32>().unwrap() * b.parse::<u32>().unwrap() >= 12
        }));
    }
}
