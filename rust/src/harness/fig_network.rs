//! Figures 6-9: per-layer network gigaflops, SYCL-DNN (our tuned kernels)
//! vs vendor libraries, on the modeled devices.
//!
//! * Fig. 6: ResNet on HiKey 960 (Mali GPU + A73 NEON), batch 1.
//! * Fig. 7: ResNet on i7-6700K (our CPU + iGPU vs MKL-DNN), batch 4.
//! * Fig. 8: VGG on HiKey 960, batch 1.
//! * Fig. 9: VGG on i7-6700K, batch 4.

use crate::device::device_by_name;
use crate::nn::network_layers;
use crate::perfmodel::{conv_estimate, vendor_conv, ConvProblem, VendorLib};
use crate::tuner::{tune_conv, ExhaustiveSearch};

use super::report::Report;

/// Which paper figure a (network, testbed) pair corresponds to.
pub fn figure_id(network: &str, testbed: &str) -> &'static str {
    match (network, testbed) {
        ("resnet", "hikey960") => "Figure 6",
        ("resnet", "i7-6700k") => "Figure 7",
        ("vgg", "hikey960") => "Figure 8",
        ("vgg", "i7-6700k") => "Figure 9",
        _ => "Figure ?",
    }
}

/// Generate one network figure on one testbed.
///
/// `testbed` is `hikey960` (Mali GPU vs ARM-CL OpenCL + NEON, batch 1) or
/// `i7-6700k` (HD530 iGPU + CPU vs MKL-DNN, batch 4), matching §5.3.
pub fn fig_network(network: &str, testbed: &str) -> crate::error::Result<Report> {
    let layers = network_layers(network)?;
    let (dev_gpu, dev_cpu, vendor_gpu, vendor_cpu, batch) = match testbed {
        "hikey960" => (
            device_by_name("mali-g71")?,
            device_by_name("hikey960-cpu")?,
            VendorLib::ArmClOpenCl,
            VendorLib::ArmClNeon,
            1u32,
        ),
        "i7-6700k" => (
            device_by_name("hd530")?,
            device_by_name("i7-6700k-cpu")?,
            VendorLib::MklDnn,
            VendorLib::MklDnn,
            4u32,
        ),
        other => {
            return Err(crate::error::Error::NotFound(format!(
                "testbed {other:?} (use hikey960 | i7-6700k)"
            )))
        }
    };

    let mut r = Report::new(
        &format!(
            "{}: {} per-layer GFLOP/s on {} (batch {batch}, modeled)",
            figure_id(network, testbed),
            network,
            testbed
        ),
        &[
            "layer",
            "ours_gpu",
            "ours_gpu_cfg",
            "ours_cpu",
            "vendor_gpu",
            "vendor_cpu",
        ],
    );
    for layer in &layers {
        let p = ConvProblem::new(layer.clone(), batch);
        let ours_gpu = tune_conv(&dev_gpu, layer, batch, &ExhaustiveSearch)
            .expect("non-empty conv space");
        let ours_cpu = tune_conv(&dev_cpu, layer, batch, &ExhaustiveSearch)
            .expect("non-empty conv space");
        // Sanity: the tuned result must reproduce through conv_estimate.
        debug_assert!(
            conv_estimate(
                &dev_gpu,
                &p,
                &ours_gpu.config,
                &crate::config::GemmConfig::default()
            )
            .is_ok()
        );
        let v_gpu = vendor_conv(&dev_gpu, vendor_gpu, layer, batch);
        let v_cpu = vendor_conv(&dev_cpu, vendor_cpu, layer, batch);
        r.row(vec![
            layer.name.clone(),
            format!("{:.1}", ours_gpu.gflops),
            ours_gpu.config.name(),
            format!("{:.1}", ours_cpu.gflops),
            format!("{v_gpu:.1}"),
            format!("{v_cpu:.1}"),
        ]);
    }
    match testbed {
        "hikey960" => {
            r.note("paper: ours typically wins ResNet (1x1) layers; ARM-CL OpenCL wins 3x3 VGG layers");
        }
        _ => {
            r.note("paper: MKL-DNN consistently faster on ResNet (max 366 GF vs our 244); ours (GPU) wins VGG");
        }
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(r: &Report, name: &str) -> usize {
        r.columns.iter().position(|c| c == name).unwrap()
    }

    #[test]
    fn fig6_ours_wins_pointwise_on_mali() {
        // Paper Fig. 6: SYCL-DNN typically outperforms ARM-CL on the
        // (1x1-dominated) ResNet layers.
        let r = fig_network("resnet", "hikey960").unwrap();
        let (ours_i, vendor_i) = (col(&r, "ours_gpu"), col(&r, "vendor_gpu"));
        let layers = crate::nn::resnet50_layers();
        let mut ours_wins = 0;
        let mut total = 0;
        for (row, layer) in r.rows.iter().zip(&layers) {
            if layer.window == 1 {
                total += 1;
                if row[ours_i].parse::<f64>().unwrap()
                    > row[vendor_i].parse::<f64>().unwrap()
                {
                    ours_wins += 1;
                }
            }
        }
        assert!(
            ours_wins * 2 > total,
            "ours wins only {ours_wins}/{total} pointwise layers"
        );
    }

    #[test]
    fn fig8_arm_opencl_wins_vgg_3x3_on_mali() {
        // Paper Fig. 8: ARM's hand-tuned OpenCL 3x3 kernels mostly beat us
        // on VGG.
        let r = fig_network("vgg", "hikey960").unwrap();
        let (ours_i, vendor_i) = (col(&r, "ours_gpu"), col(&r, "vendor_gpu"));
        let vendor_wins = r
            .rows
            .iter()
            .filter(|row| {
                row[vendor_i].parse::<f64>().unwrap()
                    > row[ours_i].parse::<f64>().unwrap()
            })
            .count();
        assert!(
            vendor_wins * 2 > r.rows.len(),
            "vendor wins only {vendor_wins}/{}",
            r.rows.len()
        );
    }

    #[test]
    fn fig7_mkldnn_beats_us_on_resnet_cpu() {
        // Paper Fig. 7 / §5.3: "For the convolutions in the ResNet model
        // MKL-DNN is consistently faster than SYCL-DNN".
        let r = fig_network("resnet", "i7-6700k").unwrap();
        let (ours_i, vendor_i) = (col(&r, "ours_cpu"), col(&r, "vendor_cpu"));
        let vendor_wins = r
            .rows
            .iter()
            .filter(|row| {
                row[vendor_i].parse::<f64>().unwrap()
                    > row[ours_i].parse::<f64>().unwrap()
            })
            .count();
        assert!(vendor_wins * 2 > r.rows.len());
    }

    #[test]
    fn unknown_testbed_rejected() {
        assert!(fig_network("vgg", "m1-max").is_err());
    }
}
