//! Figures 4 & 5: GEMM roofline comparisons.
//!
//! * Fig. 4 (Intel UHD 630): (a) Table-2 configurations vs clBLAST;
//!   (b) square vs non-square register tile at 16 registers;
//!   (c) double buffering on/off.
//! * Fig. 5 (ARM Mali G-71): configurations vs ARM Compute Library, with
//!   the A/B/C regions where different configurations win.

use std::collections::BTreeMap;

use crate::config::GemmConfig;
use crate::device::{device_by_name, DeviceSpec};
use crate::perfmodel::{vendor_gemm, GemmProblem, VendorLib};

use super::report::Report;
use super::sweep::{gemm_sweep, paper_size_grid, winners_per_point};

fn roofline_report(
    title: &str,
    dev: &DeviceSpec,
    cfgs: &[GemmConfig],
    vendor: VendorLib,
) -> Report {
    let mut r = Report::new(
        title,
        &["m", "n", "k", "intensity", "config", "gflops", "vendor_gflops"],
    );
    for cfg in cfgs {
        for p in gemm_sweep(dev, cfg) {
            let vp = vendor_gemm(
                dev,
                vendor,
                GemmProblem::new(p.m, p.n, p.k),
            );
            r.row(vec![
                p.m.to_string(),
                p.n.to_string(),
                p.k.to_string(),
                format!("{:.2}", p.intensity),
                p.config.clone(),
                format!("{:.2}", p.gflops),
                format!("{vp:.2}"),
            ]);
        }
    }
    r.note(format!("device: {dev}"));
    r.note(format!("vendor baseline: {}", vendor.as_str()));
    r
}

/// Figure 4a: all Table-2 configurations vs clBLAST on the UHD 630.
pub fn fig4a() -> Report {
    let dev = device_by_name("uhd630").expect("preset");
    roofline_report(
        "Figure 4a: SYCL-BLAS configurations vs clBLAST (Intel UHD 630, modeled)",
        &dev,
        &GemmConfig::table2(),
        VendorLib::ClBlast,
    )
}

/// Figure 4b: square (4x4_8x8) vs non-square (8x2_4x16) register tiles.
pub fn fig4b() -> Report {
    let dev = device_by_name("uhd630").expect("preset");
    let cfgs = [
        GemmConfig::parse("4x4_8x8_loc").unwrap(),
        GemmConfig::parse("8x2_4x16_loc").unwrap(),
    ];
    let mut r = roofline_report(
        "Figure 4b: square vs non-square register tile, 16 registers each",
        &dev,
        &cfgs,
        VendorLib::ClBlast,
    );
    r.note("paper: the square 4x4_8x8 tile wins (Eq. 3 reuse)");
    r
}

/// Figure 4c: double buffering on/off for 8x4_8x16_loc.
pub fn fig4c() -> Report {
    let dev = device_by_name("uhd630").expect("preset");
    let cfgs = [
        GemmConfig::parse("8x4_8x16_loc").unwrap(),
        GemmConfig::parse("8x4_8x16_loc_db").unwrap(),
    ];
    let mut r = roofline_report(
        "Figure 4c: double buffering (8x4_8x16_loc vs _db)",
        &dev,
        &cfgs,
        VendorLib::ClBlast,
    );
    r.note("paper: double buffering hides panel-load latency");
    r
}

/// Figure 5a: all configurations vs ARM Compute Library on the Mali G-71.
pub fn fig5a() -> Report {
    let dev = device_by_name("mali-g71").expect("preset");
    roofline_report(
        "Figure 5a: SYCL-BLAS configurations vs ARM Compute Library (Mali G-71, modeled)",
        &dev,
        &GemmConfig::table2(),
        VendorLib::ArmClOpenCl,
    )
}

/// ASCII roofline scatter (the visual shape of Fig. 4a / Fig. 5a): the
/// best configuration per point vs the vendor curve, log-log.
pub fn roofline_plot(device_id: &str) -> crate::error::Result<String> {
    let dev = device_by_name(device_id)?;
    let vendor = if device_id == "mali-g71" {
        VendorLib::ArmClOpenCl
    } else {
        VendorLib::ClBlast
    };
    let mut ours = Vec::new();
    let mut vend = Vec::new();
    for (m, n, k, _, g) in winners_per_point(&dev, &GemmConfig::table2()) {
        let p = GemmProblem::new(m, n, k);
        ours.push((p.intensity(), g));
        vend.push((p.intensity(), vendor_gemm(&dev, vendor, p)));
    }
    Ok(format!(
        "roofline on {} (y: GFLOP/s, x: flop/byte):\n{}",
        dev.name,
        super::plot::scatter_loglog(
            &[
                super::plot::Series {
                    glyph: 'v',
                    label: vendor.as_str().into(),
                    points: vend,
                },
                super::plot::Series {
                    glyph: '*',
                    label: "best SYCL-BLAS config".into(),
                    points: ours,
                },
            ],
            72,
            18,
        )
    ))
}

/// Figures 5b-5d: the per-size winning configuration, with the region
/// summary (small/square -> A, mid/rectangular -> B, large -> C).
pub fn fig5_regions() -> Report {
    let dev = device_by_name("mali-g71").expect("preset");
    let mut r = Report::new(
        "Figure 5b-d: winning configuration per problem size (Mali G-71)",
        &["m", "n", "k", "flops(G)", "winner", "gflops"],
    );
    let winners = winners_per_point(&dev, &GemmConfig::table2());
    for (m, n, k, name, g) in &winners {
        r.row(vec![
            m.to_string(),
            n.to_string(),
            k.to_string(),
            format!("{:.3}", 2.0 * (*m as f64) * (*n as f64) * (*k as f64) / 1e9),
            name.clone(),
            format!("{g:.2}"),
        ]);
    }
    // Region summary, bucketed the way the paper's prose describes them:
    // A = small (typically square) matrices, B = small-to-medium, C =
    // large high-intensity matrices.
    let mut region_counts: BTreeMap<&str, BTreeMap<String, usize>> =
        BTreeMap::new();
    for ((m, n, k), (_, _, _, name, _)) in
        paper_size_grid().iter().zip(&winners)
    {
        let lo = *m.min(n).min(k);
        let hi = *m.max(n).max(k);
        let region = if hi <= 128 {
            "A (small)"
        } else if lo >= 512 {
            "C (large)"
        } else {
            "B (medium)"
        };
        *region_counts
            .entry(region)
            .or_default()
            .entry(name.clone())
            .or_default() += 1;
    }
    for (region, counts) in &region_counts {
        let top = counts
            .iter()
            .max_by_key(|(_, c)| **c)
            .map(|(n, c)| format!("{n} ({c} pts)"))
            .unwrap_or_default();
        r.note(format!("region {region}: most frequent winner {top}"));
    }
    r.note("paper: A -> 4x4_8x8, B -> 8x4_4x8, C -> 8x4_8x16");
    r.note("reproduction: A and C winners match; in B our model picks the \
            paper's 8x4 register tile but a different work-group split \
            (see EXPERIMENTS.md)");
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_rows(r: &Report) -> Vec<(String, f64, f64)> {
        r.rows
            .iter()
            .map(|row| {
                (
                    row[4].clone(),
                    row[5].parse::<f64>().unwrap(),
                    row[6].parse::<f64>().unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn fig4a_best_config_is_competitive_with_vendor() {
        // Paper: 8x4_8x16_loc achieves "close to" clBLAST.  We require
        // the best config to be within 2x of the vendor curve at the
        // biggest size and to beat 60% of it.
        let r = fig4a();
        let rows = parse_rows(&r);
        let at_big: Vec<_> = r
            .rows
            .iter()
            .zip(&rows)
            .filter(|(raw, _)| raw[0] == "1024" && raw[1] == "1024" && raw[2] == "1024")
            .map(|(_, p)| p.clone())
            .collect();
        let (best_cfg, best, vendor) = at_big
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .cloned()
            .unwrap();
        assert!(best / vendor > 0.6, "{best_cfg}: {best} vs vendor {vendor}");
        // And the paper's winner is among the top configs.
        assert!(
            best_cfg.starts_with("8x4"),
            "expected an 8x4 tile to win at 1024^3, got {best_cfg}"
        );
    }

    #[test]
    fn fig4b_square_wins_on_average() {
        let r = fig4b();
        let rows = parse_rows(&r);
        let mean = |cfg: &str| {
            let v: Vec<f64> = rows
                .iter()
                .filter(|(c, _, _)| c == cfg)
                .map(|(_, g, _)| *g)
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(mean("4x4_8x8_loc") > mean("8x2_4x16_loc"));
    }

    #[test]
    fn fig4c_db_wins_on_average() {
        let r = fig4c();
        let rows = parse_rows(&r);
        let mean = |cfg: &str| {
            let v: Vec<f64> = rows
                .iter()
                .filter(|(c, _, _)| c == cfg)
                .map(|(_, g, _)| *g)
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(mean("8x4_8x16_loc_db") > mean("8x4_8x16_loc"));
    }

    #[test]
    fn fig5_has_multiple_regional_winners() {
        // The paper's core portability result: no single configuration
        // wins everywhere on Mali.
        let r = fig5_regions();
        let winners: std::collections::HashSet<String> =
            r.rows.iter().map(|row| row[4].clone()).collect();
        assert!(
            winners.len() >= 2,
            "expected regional structure, got only {winners:?}"
        );
    }

    #[test]
    fn fig5_small_sizes_prefer_smaller_blocks_than_large_sizes() {
        let r = fig5_regions();
        let block_area = |name: &str| {
            let cfg = GemmConfig::parse(name).unwrap();
            cfg.block_m() * cfg.block_n()
        };
        let row_for = |m: &str, n: &str, k: &str| {
            r.rows
                .iter()
                .find(|row| row[0] == m && row[1] == n && row[2] == k)
                .map(|row| row[4].clone())
                .unwrap()
        };
        let small = block_area(&row_for("64", "64", "64"));
        let large = block_area(&row_for("1024", "1024", "1024"));
        assert!(small <= large, "small {small} vs large {large}");
    }
}
