//! Terminal scatter plots for the roofline figures — a log-log ASCII
//! renderer so `repro figures` shows the *shape* of Figs. 4/5 directly in
//! the terminal, not just CSV.

/// One series: a glyph + (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    /// Character drawn for this series' points.
    pub glyph: char,
    /// Legend label.
    pub label: String,
    /// (x, y) data points.
    pub points: Vec<(f64, f64)>,
}

/// Render a log-log scatter of several series into a `width x height`
/// character grid with axis annotations.  Later series overwrite earlier
/// ones on collisions (draw the baseline first).
pub fn scatter_loglog(
    series: &[Series],
    width: usize,
    height: usize,
) -> String {
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|(x, y)| *x > 0.0 && *y > 0.0)
        .collect();
    if pts.is_empty() {
        return "(no data)\n".into();
    }
    let (mut x0, mut x1, mut y0, mut y1) =
        (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for (x, y) in &pts {
        x0 = x0.min(*x);
        x1 = x1.max(*x);
        y0 = y0.min(*y);
        y1 = y1.max(*y);
    }
    // Pad the log range slightly so extremes stay inside the frame.
    let (lx0, lx1) = (x0.ln() - 0.05, x1.ln() + 0.05);
    let (ly0, ly1) = (y0.ln() - 0.05, y1.ln() + 0.05);

    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for (x, y) in &s.points {
            if *x <= 0.0 || *y <= 0.0 {
                continue;
            }
            let cx = ((x.ln() - lx0) / (lx1 - lx0) * (width - 1) as f64)
                .round() as usize;
            let cy = ((y.ln() - ly0) / (ly1 - ly0) * (height - 1) as f64)
                .round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = s.glyph;
        }
    }

    let mut out = String::new();
    out.push_str(&format!("{y1:>10.1} ┐\n"));
    for row in grid {
        out.push_str("           │");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("{y0:>10.1} ┘"));
    out.push_str(&"─".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "            x: {x0:.2} .. {x1:.2} (log)   legend: {}\n",
        series
            .iter()
            .map(|s| format!("{} {}", s.glyph, s.label))
            .collect::<Vec<_>>()
            .join("  ")
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Vec<Series> {
        vec![
            Series {
                glyph: '*',
                label: "ours".into(),
                points: vec![(1.0, 10.0), (10.0, 100.0), (100.0, 400.0)],
            },
            Series {
                glyph: 'v',
                label: "vendor".into(),
                points: vec![(1.0, 12.0), (100.0, 460.0)],
            },
        ]
    }

    #[test]
    fn renders_all_series() {
        let text = scatter_loglog(&demo(), 60, 16);
        assert!(text.contains('*'));
        assert!(text.contains('v'));
        assert!(text.contains("ours"));
        assert!(text.contains("vendor"));
        // Frame height = height + 2 header/footer + legend.
        assert_eq!(text.lines().count(), 16 + 3);
    }

    #[test]
    fn empty_input_is_safe() {
        assert_eq!(scatter_loglog(&[], 40, 10), "(no data)\n");
        let s = Series { glyph: 'x', label: "neg".into(), points: vec![(-1.0, 1.0)] };
        assert_eq!(scatter_loglog(&[s], 40, 10), "(no data)\n");
    }

    #[test]
    fn monotone_series_renders_monotone() {
        // The highest-y point must appear on an earlier (higher) row than
        // the lowest-y point.
        let s = Series {
            glyph: '#',
            label: "m".into(),
            points: vec![(1.0, 1.0), (100.0, 1000.0)],
        };
        let text = scatter_loglog(&[s], 40, 12);
        let rows: Vec<usize> = text
            .lines()
            .enumerate()
            .filter(|(_, l)| l.starts_with("           │") && l.contains('#'))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(rows.len(), 2);
        // First occurrence (top of frame) is the high-y point.
        assert!(rows[0] < rows[1]);
    }
}
