//! Figure 3: convolution throughput across tile and vector sizes on the
//! AMD R9 Nano (modeled), including the naive baseline and the spill
//! cliff.

use crate::config::{ConvConfig, GemmConfig};
use crate::device::device_by_name;
use crate::nn::ConvLayer;
use crate::perfmodel::{conv_estimate, ConvProblem};

use super::fig_registers::{TILES, VECS};
use super::report::{gf, Report};

/// The workload the paper sweeps: a representative 3x3 layer with enough
/// channels to saturate the device.
pub fn fig3_layer() -> ConvLayer {
    ConvLayer::same("bench3x3", 3, 1, 56, 56, 256, 256)
}

/// Generate Figure 3's data on the modeled R9 Nano.
pub fn fig3() -> Report {
    let dev = device_by_name("r9-nano").expect("preset exists");
    let p = ConvProblem::new(fig3_layer(), 4);
    let gemm_cfg = GemmConfig::default();

    let mut r = Report::new(
        "Figure 3: tiled 3x3 conv GFLOP/s on AMD R9 Nano (modeled)",
        &["tile", "vec_c", "vec_k", "gflops", "regs", "spilled"],
    );
    let mut best: Option<(String, f64)> = None;
    for (th, tw) in TILES {
        for vc in VECS {
            for vk in VECS {
                let cfg = ConvConfig::tiled(th, tw, vc, vk);
                let e = conv_estimate(&dev, &p, &cfg, &gemm_cfg)
                    .expect("tiled is always feasible on r9");
                if best.as_ref().map(|(_, g)| e.gflops > *g).unwrap_or(true) {
                    best = Some((cfg.name(), e.gflops));
                }
                r.row(vec![
                    format!("{th}x{tw}"),
                    vc.to_string(),
                    vk.to_string(),
                    format!("{:.1}", e.gflops),
                    e.regs_per_thread.to_string(),
                    if e.spilled { "yes" } else { "no" }.into(),
                ]);
            }
        }
    }
    let naive = conv_estimate(&dev, &p, &ConvConfig::naive(), &gemm_cfg)
        .expect("naive feasible");
    let (best_name, best_g) = best.expect("non-empty sweep");
    r.note(format!("peak: {} at {}", gf(best_g), best_name));
    r.note(format!(
        "naive (Alg. 1): {} -> {:.1}x speedup at the peak",
        gf(naive.gflops),
        best_g / naive.gflops
    ));
    r.note("paper: peak 2.57 TF at 4x5/v4x2; naive 0.29 TF (~10x); spill ~50 GF");
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_reproduces_paper_shape() {
        let r = fig3();
        // Extract (tile, gflops, spilled) triples.
        let rows: Vec<(String, f64, bool)> = r
            .rows
            .iter()
            .map(|row| {
                (
                    format!("{}v{}x{}", row[0], row[1], row[2]),
                    row[3].parse::<f64>().unwrap(),
                    row[5] == "yes",
                )
            })
            .collect();
        let best = rows
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        let naive = rows.iter().find(|r| r.0 == "1x1v1x1").unwrap();

        // (i) tiled >> naive, order of magnitude (paper: ~10x).
        assert!(best.1 / naive.1 > 5.0, "speedup {}", best.1 / naive.1);
        // (ii) the winner is a mid-size tile with vectors, not 1x1 and
        // not the biggest spilled tile.
        assert!(!best.2, "winner must not spill");
        assert_ne!(best.0, "1x1v1x1");
        // (iii) spilled configs exist and are dramatically worse.
        let worst_spilled = rows
            .iter()
            .filter(|r| r.2)
            .map(|r| r.1)
            .fold(f64::INFINITY, f64::min);
        assert!(worst_spilled < best.1 / 4.0);
    }
}
