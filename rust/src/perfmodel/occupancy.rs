//! Occupancy model (paper §2.2.1, "thread reusability").
//!
//! GPUs hide memory latency by switching among resident threads; how many
//! threads can be resident is limited by the register file, the local
//! memory, and the hardware thread slots.  The paper's Fig. 3 discussion
//! ("if each thread requires more registers then the number of concurrent
//! threads decreases...") is exactly this computation.
//!
//! Hard infeasibility (the configurations the paper's tuner rejects up
//! front) is limited to the two real launch failures: a work-group larger
//! than the device's work-group limit, and a local-memory tile larger
//! than the device's local memory.  Register pressure never refuses to
//! launch — compilers spill or re-tile — it only degrades residency.

use crate::device::DeviceSpec;
use crate::error::{Error, Result};

/// Resident-thread analysis for one kernel configuration on one device.
#[derive(Debug, Clone, Copy)]
pub struct Occupancy {
    /// Latency-hiding effectiveness, 0..=1: resident threads relative to
    /// what the device needs to cover its memory latency.
    pub fraction: f64,
    /// Concurrent threads per compute unit.
    pub threads_per_cu: f64,
    /// What limited residency.
    pub limited_by: Limit,
}

/// The binding residency constraint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Limit {
    /// Hardware thread slots.
    ThreadSlots,
    /// Register-file capacity.
    Registers,
    /// Local-memory capacity.
    LocalMem,
}

/// Compute occupancy for a kernel needing `regs_per_thread` registers,
/// work-groups of `wg_threads` threads, and `local_mem_per_wg` bytes of
/// local memory per work-group.
pub fn occupancy(
    dev: &DeviceSpec,
    regs_per_thread: u32,
    wg_threads: u32,
    local_mem_per_wg: u32,
) -> Result<Occupancy> {
    if wg_threads > dev.max_wg_size {
        return Err(Error::Infeasible {
            device: dev.id.clone(),
            reason: format!(
                "work-group of {wg_threads} exceeds the device limit {}",
                dev.max_wg_size
            ),
        });
    }
    // Spilled kernels cap their register demand at the architectural
    // budget (the overflow lives in memory; the caller charges for it).
    let regs = regs_per_thread.min(dev.max_regs_per_thread).max(1);

    let by_slots = dev.max_threads_per_cu as f64;
    let by_regs = dev.reg_file_per_cu as f64 / regs as f64;

    let by_local = if local_mem_per_wg == 0 || dev.local_mem_bytes == 0 {
        // No request, or no local memory: staging buffers live in the
        // cache; no residency constraint (the speed cost is modeled in
        // `memory::effective_bandwidth`).
        f64::INFINITY
    } else if local_mem_per_wg > dev.local_mem_bytes {
        return Err(Error::Infeasible {
            device: dev.id.clone(),
            reason: format!(
                "work-group needs {local_mem_per_wg} B local, device has {}",
                dev.local_mem_bytes
            ),
        });
    } else {
        (dev.local_mem_bytes / local_mem_per_wg) as f64 * wg_threads as f64
    };

    let (threads_per_cu, limited_by) = [
        (by_slots, Limit::ThreadSlots),
        (by_regs, Limit::Registers),
        (by_local, Limit::LocalMem),
    ]
    .into_iter()
    .fold((f64::INFINITY, Limit::ThreadSlots), |acc, (v, l)| {
        if v < acc.0 {
            (v, l)
        } else {
            acc
        }
    });

    let fraction =
        (threads_per_cu / dev.latency_hiding_threads as f64).min(1.0);
    Ok(Occupancy {
        fraction,
        threads_per_cu,
        limited_by,
    })
}

/// Occupancy corrected for how many threads the *problem* actually
/// provides: residency is work-group granular, so with fewer work-groups
/// than compute units only one work-group's threads are resident per CU
/// (why the paper's region A favours larger work-groups, Fig. 5b).
pub fn effective_fraction(
    occ: &Occupancy,
    dev: &DeviceSpec,
    wg_threads: u32,
    wgs: u64,
) -> f64 {
    let per_cu_avail = (wgs as f64 / dev.compute_units as f64)
        .max(1.0)
        * wg_threads as f64;
    let resident = occ.threads_per_cu.min(per_cu_avail);
    (resident / dev.latency_hiding_threads as f64).min(1.0)
}

/// Work-group tail quantization: with `wgs` work-groups over `cus`
/// compute units, the last "wave" may be partially empty.  Returns the
/// utilization fraction (paper §2.2.1's trade-off between work-group
/// count and per-thread workload).
pub fn cu_utilization(wgs: u64, cus: u32) -> f64 {
    if wgs == 0 {
        return 0.0;
    }
    let cus = cus as u64;
    let waves = wgs.div_ceil(cus);
    wgs as f64 / (waves * cus) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{all_devices, device_by_name};

    #[test]
    fn more_registers_never_raises_occupancy() {
        let dev = device_by_name("r9-nano").unwrap();
        let mut last = f64::INFINITY;
        for regs in [16, 32, 64, 128, 256] {
            let occ = occupancy(&dev, regs, 64, 0).unwrap();
            assert!(occ.threads_per_cu <= last);
            last = occ.threads_per_cu;
        }
    }

    #[test]
    fn register_pressure_lowers_occupancy_on_r9() {
        // Fig. 3's mechanism: heavy register use cuts resident threads
        // below the latency-hiding requirement.
        let dev = device_by_name("r9-nano").unwrap();
        let light = occupancy(&dev, 32, 64, 0).unwrap();
        let heavy = occupancy(&dev, 250, 64, 0).unwrap();
        assert!(heavy.fraction < light.fraction);
        assert_eq!(heavy.limited_by, Limit::Registers);
    }

    #[test]
    fn local_mem_overflow_is_infeasible() {
        let dev = device_by_name("r9-nano").unwrap(); // 32 KiB LDS
        assert!(occupancy(&dev, 32, 64, 33 * 1024).is_err());
        assert!(occupancy(&dev, 32, 64, 16 * 1024).is_ok());
    }

    #[test]
    fn oversized_work_group_is_infeasible() {
        let dev = device_by_name("uhd630").unwrap(); // max WG 256
        assert!(occupancy(&dev, 16, 512, 0).is_err());
        assert!(occupancy(&dev, 16, 256, 0).is_ok());
    }

    #[test]
    fn no_local_mem_device_never_local_limited() {
        let dev = device_by_name("mali-g71").unwrap();
        // Huge "local" request is fine — it is emulated in the cache.
        let occ = occupancy(&dev, 32, 64, 1 << 20).unwrap();
        assert_ne!(occ.limited_by, Limit::LocalMem);
    }

    #[test]
    fn full_occupancy_when_plenty_of_threads() {
        let dev = device_by_name("r9-nano").unwrap();
        let occ = occupancy(&dev, 32, 256, 8 * 1024).unwrap();
        assert!(occ.fraction > 0.9);
    }

    #[test]
    fn tail_quantization() {
        assert_eq!(cu_utilization(64, 64), 1.0);
        assert_eq!(cu_utilization(65, 64), 65.0 / 128.0);
        assert_eq!(cu_utilization(32, 64), 0.5);
        assert_eq!(cu_utilization(0, 64), 0.0);
        // Large counts approach 1.
        assert!(cu_utilization(64 * 100 + 1, 64) > 0.99);
    }

    #[test]
    fn all_devices_run_every_table2_work_group() {
        // Every Table-2 work-group size (32..256) must launch on every
        // modeled device — the paper ran them all.
        for dev in all_devices() {
            for wg in [32u32, 64, 128, 256] {
                occupancy(&dev, 32, wg, 1024).unwrap_or_else(|e| {
                    panic!("{}: wg {wg}: {e}", dev.id)
                });
            }
        }
    }
}
