//! Register-usage estimator (reproduces paper Fig. 2).
//!
//! The paper measured register counts with AMD CodeXL for its tiled 3x3
//! convolution kernel across tile and vector sizes.  This module models
//! the same quantity structurally: accumulators + input-window staging +
//! filter staging + addressing overhead, in scalar f32 registers.

use crate::config::{ConvConfig, GemmConfig};

/// Bookkeeping registers every kernel needs (indices, strides, loop
/// counters, base pointers).
pub const ADDRESS_REGS: u32 = 16;

/// Registers per thread for the tiled direct convolution kernel.
///
/// * accumulators: `tile_h * tile_w * vec_k` output values;
/// * input window: the `(tile_h + R - 1) x (tile_w + R - 1)` halo patch,
///   `vec_c` channels deep (vector loads hold `vec_c` values in `vec_c`
///   scalar registers on GCN-class hardware);
/// * filter: one `R`-row slice of `vec_c x vec_k` taps.
pub fn conv_regs(cfg: &ConvConfig, window: u32) -> u32 {
    let acc = cfg.tile_h * cfg.tile_w * cfg.vec_k;
    let input = (cfg.tile_h + window - 1) * (cfg.tile_w + window - 1) * cfg.vec_c;
    let filter = window * cfg.vec_c * cfg.vec_k;
    acc + input + filter + ADDRESS_REGS
}

/// Registers per thread for the blocked GEMM kernel:
/// `rt_m x rt_n` accumulators plus one A-fragment column and one
/// B-fragment row (the rank-1 update operands).
pub fn gemm_regs(cfg: &GemmConfig) -> u32 {
    cfg.rt_m * cfg.rt_n + cfg.rt_m + cfg.rt_n + ADDRESS_REGS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ConvConfig;

    #[test]
    fn registers_grow_with_tile_and_vector() {
        // Fig. 2's qualitative content: register usage grows monotonically
        // with tile area and with each vector width.
        let base = conv_regs(&ConvConfig::tiled(1, 1, 1, 1), 3);
        let tiles = conv_regs(&ConvConfig::tiled(4, 4, 1, 1), 3);
        let vecs = conv_regs(&ConvConfig::tiled(4, 4, 4, 1), 3);
        let both = conv_regs(&ConvConfig::tiled(4, 4, 4, 4), 3);
        assert!(base < tiles && tiles < vecs && vecs < both);
    }

    #[test]
    fn paper_peak_config_fits_gcn_budget() {
        // Fig. 3: the 4x5 tile / vec4-input / vec2-output config is the
        // R9 Nano's sweet spot — it must *fit* the 256-VGPR budget...
        let peak = conv_regs(&ConvConfig::tiled(4, 5, 4, 2), 3);
        assert!(peak <= 256, "peak config uses {peak} regs");
        // ...while 5x5 with vec4/vec4 must spill (the Fig. 3 cliff).
        let spill = conv_regs(&ConvConfig::tiled(5, 5, 4, 4), 3);
        assert!(spill > 256, "5x5/v4x4 uses only {spill} regs");
    }

    #[test]
    fn gemm_register_count_tracks_table2() {
        let c44 = GemmConfig::parse("4x4_8x8_loc").unwrap();
        let c84 = GemmConfig::parse("8x4_8x16_loc").unwrap();
        assert_eq!(gemm_regs(&c44) - ADDRESS_REGS, 16 + 8);
        assert_eq!(gemm_regs(&c84) - ADDRESS_REGS, 32 + 12);
        assert!(gemm_regs(&c84) > gemm_regs(&c44));
    }

    use crate::config::GemmConfig;
}
