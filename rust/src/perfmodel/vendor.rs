//! Calibrated vendor-library curves — the comparison targets of the
//! paper's evaluation (clBLAST, ARM Compute Library, MKL-DNN).
//!
//! We do not have these libraries' hardware; their curves are modeled as
//! roofline fractions *calibrated to the paper's reported behaviour* and
//! documented here as explicit constants (DESIGN.md §2, substitution 2):
//!
//! * clBLAST on Intel UHD 630 reaches ~85% of roofline at high intensity
//!   (Fig. 4a shows our 8x4_8x16_loc "close to" it).
//! * ARM Compute Library's OpenCL 3x3 convolutions are heavily hand-tuned
//!   (they "in most cases outperform SYCL-DNN" on VGG — Fig. 8), while its
//!   1x1 paths are weaker (SYCL-DNN "typically out performs both the
//!   OpenCL and Neon implementations in the ResNet benchmarks" — Fig. 6).
//! * MKL-DNN on the i7-6700K reaches up to 366 GF on ResNet convolutions
//!   (~68% of the CPU's 537 GF peak) and is "consistently faster" there,
//!   while losing to the iGPU on VGG (Fig. 9).

use crate::device::DeviceSpec;
use crate::nn::ConvLayer;

use super::gemm_model::GemmProblem;

/// Which hand-tuned library a curve models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VendorLib {
    /// clBLAST tuned OpenCL GEMM (Fig. 4 baseline).
    ClBlast,
    /// ARM Compute Library, OpenCL kernels on the Mali GPU.
    ArmClOpenCl,
    /// ARM Compute Library, NEON kernels on the big CPU cluster.
    ArmClNeon,
    /// Intel MKL-DNN on the CPU.
    MklDnn,
}

impl VendorLib {
    /// Display name as the paper's figure legends spell it.
    pub fn as_str(&self) -> &'static str {
        match self {
            VendorLib::ClBlast => "clBLAST",
            VendorLib::ArmClOpenCl => "ARM-CL (OpenCL)",
            VendorLib::ArmClNeon => "ARM-CL (NEON)",
            VendorLib::MklDnn => "MKL-DNN",
        }
    }
}

/// Roofline fraction a hand-tuned GEMM attains, by library.
fn gemm_eff(lib: VendorLib) -> f64 {
    match lib {
        VendorLib::ClBlast => 0.85,
        VendorLib::ArmClOpenCl => 0.80,
        VendorLib::ArmClNeon => 0.70,
        VendorLib::MklDnn => 0.90,
    }
}

/// Modeled vendor GEMM throughput (GFLOP/s) for a problem on a device.
/// Small problems pay the same launch-bound penalty our kernels do.
pub fn vendor_gemm(dev: &DeviceSpec, lib: VendorLib, p: GemmProblem) -> f64 {
    let roof = dev.roofline_gflops(p.intensity());
    let t_ideal = p.flops() as f64 / (roof * gemm_eff(lib) * 1e9);
    let time = t_ideal + super::LAUNCH_OVERHEAD_S;
    p.flops() as f64 / time / 1e9
}

/// Roofline fraction a hand-tuned convolution attains, by library and
/// window size.  The window-dependence encodes the paper's observations
/// quoted in the module docs.
fn conv_eff(lib: VendorLib, window: u32) -> f64 {
    match (lib, window) {
        // ARM's OpenCL 3x3 kernels use Winograd internally, so their
        // *direct-flop-normalized* throughput exceeds the direct-conv
        // roofline (effective factor > 1) — this is why they "in most
        // cases outperform SYCL-DNN" on VGG (Fig. 8).
        (VendorLib::ArmClOpenCl, 3) => 1.9,
        (VendorLib::ArmClOpenCl, 1) => 0.38,
        (VendorLib::ArmClOpenCl, _) => 0.55,
        (VendorLib::ArmClNeon, 3) => 0.60,
        (VendorLib::ArmClNeon, 1) => 0.45,
        (VendorLib::ArmClNeon, _) => 0.45,
        // MKL-DNN's JIT'd 3x3 path is Winograd-assisted too.
        (VendorLib::MklDnn, 3) => 1.1,
        (VendorLib::MklDnn, 1) => 0.62,
        (VendorLib::MklDnn, _) => 0.55,
        (VendorLib::ClBlast, _) => 0.75, // via im2col+GEMM
    }
}

/// Modeled vendor convolution throughput (GFLOP/s).
pub fn vendor_conv(
    dev: &DeviceSpec,
    lib: VendorLib,
    layer: &ConvLayer,
    batch: u32,
) -> f64 {
    let roof = dev.roofline_gflops(layer.intensity(batch));
    let t_ideal =
        layer.flops(batch) as f64 / (roof * conv_eff(lib, layer.window) * 1e9);
    let time = t_ideal + super::LAUNCH_OVERHEAD_S;
    layer.flops(batch) as f64 / time / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::device_by_name;

    #[test]
    fn vendor_never_exceeds_roofline() {
        let dev = device_by_name("uhd630").unwrap();
        for &(m, n, k) in &[(64, 64, 64), (1024, 1024, 1024)] {
            let p = GemmProblem::new(m, n, k);
            let g = vendor_gemm(&dev, VendorLib::ClBlast, p);
            assert!(g <= dev.roofline_gflops(p.intensity()));
        }
    }

    #[test]
    fn mkldnn_resnet_ceiling_matches_paper() {
        // Paper §5.3: MKL-DNN achieves up to 366 GF on the i7-6700K.
        let dev = device_by_name("i7-6700k-cpu").unwrap();
        let l = ConvLayer::same("conv3_2", 1, 1, 28, 28, 256, 512);
        let g = vendor_conv(&dev, VendorLib::MklDnn, &l, 4);
        assert!(g > 250.0 && g < 450.0, "got {g}");
    }

    #[test]
    fn arm_opencl_is_much_better_at_3x3_than_1x1() {
        let dev = device_by_name("mali-g71").unwrap();
        let l3 = ConvLayer::same("c3", 3, 1, 56, 56, 128, 128);
        let l1 = ConvLayer::same("c1", 1, 1, 56, 56, 128, 128);
        let g3 = vendor_conv(&dev, VendorLib::ArmClOpenCl, &l3, 1);
        let g1 = vendor_conv(&dev, VendorLib::ArmClOpenCl, &l1, 1);
        // Per-flop efficiency gap (the 1x1 layer also has lower intensity).
        assert!(g3 > g1);
    }
}
