//! Data-reuse arithmetic (paper §2.2.3 and §3.1.2, Eq. 3).

/// Paper Eq. 3: flops per loaded element for an `m' x n'` register tile:
/// `2 m' n' / (m' + n')`.  Independent of `k'`, which is why the paper
/// picks `k' = 1` at the private-memory level.
pub fn register_tile_reuse(m: u32, n: u32) -> f64 {
    let (m, n) = (m as f64, n as f64);
    2.0 * m * n / (m + n)
}

/// Global-memory traffic (elements) of a blocked GEMM with macro-tiles
/// `bm x bn`: each A panel is re-read once per C column-block and each B
/// panel once per C row-block; C is read and written once.
pub fn gemm_global_traffic(m: u64, n: u64, k: u64, bm: u64, bn: u64) -> u64 {
    let col_blocks = n.div_ceil(bn);
    let row_blocks = m.div_ceil(bm);
    m * k * col_blocks + k * n * row_blocks + 2 * m * n
}

/// Input traffic (elements) of a tiled direct convolution: each thread
/// loads the halo patch for its `th x tw` output tile, so overlapping rows
/// and columns are fetched once per tile instead of once per output
/// (paper §4.1.1).  `s` is the stride, `r` the window.
pub fn conv_input_traffic(
    batch: u64,
    out_h: u64,
    out_w: u64,
    c: u64,
    r: u64,
    s: u64,
    th: u64,
    tw: u64,
) -> u64 {
    let tiles_h = out_h.div_ceil(th);
    let tiles_w = out_w.div_ceil(tw);
    let patch_h = (th - 1) * s + r;
    let patch_w = (tw - 1) * s + r;
    batch * tiles_h * tiles_w * patch_h * patch_w * c
}

/// The naive kernel's input traffic: every output element fetches its full
/// window (tile 1x1 in the formula above).
pub fn conv_naive_input_traffic(
    batch: u64,
    out_h: u64,
    out_w: u64,
    c: u64,
    r: u64,
) -> u64 {
    batch * out_h * out_w * r * r * c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_square_is_optimal_at_fixed_register_count() {
        // Paper §3.1.2: "the best reuse is obtained if m' = n'".
        // 16 registers: 4x4 vs 8x2 vs 16x1.
        assert!(register_tile_reuse(4, 4) > register_tile_reuse(8, 2));
        assert!(register_tile_reuse(8, 2) > register_tile_reuse(16, 1));
        // 32 registers: 8x4 beats 16x2 and 32x1.
        assert!(register_tile_reuse(8, 4) > register_tile_reuse(16, 2));
    }

    #[test]
    fn eq3_grows_with_tile_size() {
        assert!(register_tile_reuse(8, 8) > register_tile_reuse(4, 4));
    }

    #[test]
    fn bigger_blocks_reduce_gemm_traffic() {
        let small = gemm_global_traffic(1024, 1024, 1024, 32, 32);
        let large = gemm_global_traffic(1024, 1024, 1024, 64, 64);
        assert!(large < small);
        // And both beat the naive per-thread traffic bound 2*M*N*K.
        assert!(small < 2 * 1024 * 1024 * 1024);
    }

    #[test]
    fn conv_tiling_reduces_input_traffic() {
        // 3x3/s1: 2x2 tiles read (4x4)/(2x2)=4 elements per output vs 9.
        let naive = conv_naive_input_traffic(1, 56, 56, 64, 3);
        let tiled = conv_input_traffic(1, 56, 56, 64, 3, 1, 2, 2);
        assert!(tiled < naive);
        let bigger = conv_input_traffic(1, 56, 56, 64, 3, 1, 4, 4);
        assert!(bigger < tiled);
    }

    #[test]
    fn pointwise_conv_has_no_overlap_gain() {
        // 1x1 windows: tiling cannot reduce input traffic.
        let naive = conv_naive_input_traffic(1, 28, 28, 256, 1);
        let tiled = conv_input_traffic(1, 28, 28, 256, 1, 1, 2, 2);
        assert_eq!(naive, tiled);
    }
}
