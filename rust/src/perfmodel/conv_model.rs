//! Modeled convolution throughput (generator behind Figs. 3 & 6-9).
//!
//! Dispatches on the configured algorithm:
//! * **naive / tiled** — direct convolution with halo-tile input reuse;
//! * **im2col** — defer to the GEMM model on the lowered problem, plus
//!   the patch-matrix materialization traffic;
//! * **winograd** — transform traffic + the batched GEMM at the reduced
//!   flop count, with a small-matrix utilization penalty (paper §4.1.2:
//!   "for smaller matrices it can be harder to fully utilize a GPU").

use crate::config::{ConvAlgorithm, ConvConfig, GemmConfig};
use crate::device::DeviceSpec;
use crate::error::{Error, Result};
use crate::nn::ConvLayer;

use super::gemm_model::{gemm_estimate, GemmProblem};
use super::memory::{effective_bandwidth, overlap_factor, vector_efficiency, Access};
use super::occupancy::{cu_utilization, occupancy};
use super::registers::conv_regs;
use super::reuse::conv_input_traffic;
use super::{Bound, Estimate, LAUNCH_OVERHEAD_S};

/// Fraction of redundant cross-feature-group input re-reads that miss the
/// cache and reach DRAM (GPU-class devices).
const REDUNDANT_FETCH_MISS_RATE: f64 = 0.35;

/// Issue + address-generation cost of one scalar patch-element load, in
/// MAC-slot equivalents; a `vec_c`-wide vector load amortizes it.
const LOAD_ISSUE_COST: f64 = 24.0;

/// One convolution problem: a layer shape at a batch size.
#[derive(Debug, Clone)]
pub struct ConvProblem {
    /// The layer geometry.
    pub layer: ConvLayer,
    /// Batch size.
    pub batch: u32,
}

impl ConvProblem {
    /// Bundle a layer with a batch size.
    pub fn new(layer: ConvLayer, batch: u32) -> Self {
        Self { layer, batch }
    }

    /// Direct-conv flops — the normalizer for every figure's gigaflops
    /// axis (a faster algorithm shows as more effective gigaflops, as in
    /// the paper).
    pub fn flops(&self) -> u64 {
        self.layer.flops(self.batch)
    }

    /// Operational intensity (flop/byte), the roofline x-axis.
    pub fn intensity(&self) -> f64 {
        self.layer.intensity(self.batch)
    }
}

/// Model the throughput of `cfg` on `dev` for problem `p`.
pub fn conv_estimate(
    dev: &DeviceSpec,
    p: &ConvProblem,
    cfg: &ConvConfig,
    gemm_cfg: &GemmConfig,
) -> Result<Estimate> {
    cfg.validate()?;
    if !cfg.algorithm.supports(p.layer.window, p.layer.stride) {
        return Err(Error::Infeasible {
            device: dev.id.clone(),
            reason: format!(
                "{} does not support {}x{}/s{}",
                cfg.algorithm, p.layer.window, p.layer.window, p.layer.stride
            ),
        });
    }
    match cfg.algorithm {
        ConvAlgorithm::Naive | ConvAlgorithm::Tiled => direct(dev, p, cfg),
        ConvAlgorithm::Im2col => im2col(dev, p, gemm_cfg),
        ConvAlgorithm::Winograd => winograd(dev, p, cfg, gemm_cfg),
    }
}

/// Direct (naive or tiled) convolution model.
fn direct(dev: &DeviceSpec, p: &ConvProblem, cfg: &ConvConfig) -> Result<Estimate> {
    let l = &p.layer;
    let flops = p.flops();
    let (out_h, out_w) = (l.out_h() as u64, l.out_w() as u64);

    // Thread geometry: one thread per (tile, vec_k feature group).
    let tiles = (p.batch as u64)
        * out_h.div_ceil(cfg.tile_h as u64)
        * out_w.div_ceil(cfg.tile_w as u64);
    let feature_groups = (l.out_c as u64).div_ceil(cfg.vec_k as u64);
    let threads = tiles * feature_groups;
    // Work-groups of 64 threads (implementation constant of the kernel).
    let wg_threads: u32 = 64;
    let wgs = threads.div_ceil(wg_threads as u64);

    let regs = conv_regs(cfg, l.window);
    let spilled = regs > dev.max_regs_per_thread;
    let occ = occupancy(dev, regs, wg_threads, 0)?;

    // Global traffic: tiled input reuse + filter + output.  Threads in
    // different feature groups re-read the same input patch; the cache
    // absorbs most of that redundancy, the remainder goes to DRAM
    // (CPUs iterate features in-cache, so their factor is tiny).
    let patch_elems = conv_input_traffic(
        p.batch as u64,
        out_h,
        out_w,
        l.in_c as u64,
        l.window as u64,
        l.stride as u64,
        cfg.tile_h as u64,
        cfg.tile_w as u64,
    );
    let absorb = if dev.class == crate::device::DeviceClass::Cpu {
        0.02
    } else {
        REDUNDANT_FETCH_MISS_RATE
    };
    let input_elems = (patch_elems as f64
        * (1.0 + absorb * (feature_groups.saturating_sub(1)) as f64))
        as u64;
    let filter_elems =
        (l.window as u64).pow(2) * l.in_c as u64 * l.out_c as u64;
    let output_elems = p.batch as u64 * out_h * out_w * l.out_c as u64;
    let bytes = 4 * (input_elems + filter_elems + output_elems);
    // Spilled accumulators bounce through scratch per channel step, at
    // per-lane scatter (scalar-transaction) bandwidth.
    let spill_bytes = if spilled {
        let overflow = (regs - dev.max_regs_per_thread) as u64;
        8 * overflow
            * threads
            * (l.in_c as u64).div_ceil(cfg.vec_c as u64).min(256)
    } else {
        0
    };

    // NHWC keeps channels innermost, so the patch loads are contiguous
    // streams: line utilization is full; vec_c instead governs the
    // *instruction* cost of the loads below.
    let bw = effective_bandwidth(dev, Access::Coalesced, false);
    let scalar_bw = dev.mem_bw_gbps * (4.0 / dev.cache_line_bytes as f64);
    let t_mem = bytes as f64 / (bw * 1e9)
        + spill_bytes as f64 / (scalar_bw * 1e9);

    let vec_eff = vector_efficiency(dev, cfg.vec_c.max(cfg.vec_k));
    let util = cu_utilization(wgs, dev.compute_units);
    // Load-issue cost: every patch element costs address generation +
    // issue slots; vector loads amortize it vec_c-fold.  This is what
    // makes Algorithm 1 (scalar loads, one output per thread) ~10x
    // slower than the tuned tile in Fig. 3.
    let macs_per_thread = (cfg.tile_h * cfg.tile_w) as u64
        * (l.window as u64).pow(2)
        * l.in_c as u64
        * cfg.vec_k as u64;
    let patch_per_thread = ((cfg.tile_h + l.window - 1)
        * (cfg.tile_w + l.window - 1)) as u64
        * l.in_c as u64;
    let issue_eff = macs_per_thread as f64
        / (macs_per_thread as f64
            + patch_per_thread as f64 * LOAD_ISSUE_COST
                / cfg.vec_c as f64);
    // Low-occupancy devices recover some throughput via the ILP that
    // vector accumulators provide (paper §2.2.4, second benefit).
    let ilp = 1.0
        + 0.15 * ((cfg.vec_k.min(4) - 1) as f64) * (1.0 - occ.fraction);
    let host_eff = if dev.class == crate::device::DeviceClass::Cpu {
        super::CPU_SIMT_PENALTY
    } else {
        1.0
    };
    let eff_peak = dev.peak_gflops * 1e9
        * occ.fraction.max(0.05)
        * vec_eff
        * util.max(1e-3)
        * issue_eff
        * (ilp.min(1.5))
        * host_eff;
    let t_comp = flops as f64 / eff_peak;

    let ov = overlap_factor(occ.fraction, false);
    let mut time = t_comp.max(t_mem) + (1.0 - ov) * t_comp.min(t_mem);
    time += LAUNCH_OVERHEAD_S;

    let bound = if util < 0.5 {
        Bound::Launch
    } else if t_mem > t_comp {
        Bound::Memory
    } else {
        Bound::Compute
    };

    Ok(Estimate {
        gflops: flops as f64 / time / 1e9,
        time_s: time,
        flops,
        global_bytes: bytes + spill_bytes,
        intensity: p.intensity(),
        occupancy: occ.fraction,
        regs_per_thread: regs,
        spilled,
        bound,
    })
}

/// im2col + GEMM model.
fn im2col(dev: &DeviceSpec, p: &ConvProblem, gemm_cfg: &GemmConfig) -> Result<Estimate> {
    let (m, n, k) = p.layer.im2col_gemm(p.batch);
    let mut est = gemm_estimate(dev, GemmProblem::new(m, n, k), gemm_cfg)?;

    // Patch materialization: write + read the (M x K) patch matrix,
    // unless the layer is pointwise (pure reshape).
    if p.layer.window > 1 || p.layer.stride > 1 {
        let patch_bytes = 2 * 4 * m * k;
        let t_extra = patch_bytes as f64 / (dev.mem_bw_gbps * 1e9);
        est.global_bytes += patch_bytes;
        est.time_s += t_extra;
    }
    // Re-normalize to *convolution* flops (identical count for im2col).
    let flops = p.flops();
    est.flops = flops;
    est.gflops = flops as f64 / est.time_s / 1e9;
    est.intensity = p.intensity();
    Ok(est)
}

/// Winograd model: reduced-flop batched GEMM + transform traffic.
fn winograd(
    dev: &DeviceSpec,
    p: &ConvProblem,
    cfg: &ConvConfig,
    gemm_cfg: &GemmConfig,
) -> Result<Estimate> {
    let l = &p.layer;
    let m = cfg.wino_m as u64;
    let alpha = m + 2;
    let (out_h, out_w) = (l.out_h() as u64, l.out_w() as u64);
    let tiles = p.batch as u64 * out_h.div_ceil(m) * out_w.div_ceil(m);

    // The batched multiply: alpha^2 GEMMs of (tiles x C) x (C x K).
    let gp = GemmProblem::new(tiles, l.out_c as u64, l.in_c as u64);
    let est = gemm_estimate(dev, gp, gemm_cfg)?;
    // alpha^2 batched instances; each is small, so utilization of wide
    // devices degrades ("harder to fully utilize a GPU") — model the
    // batch as sequential waves over the CU array.
    let batch_time = est.time_s * alpha.pow(2) as f64;

    // Transform traffic: read input tiles (alpha^2 elements per tile,
    // overlapping -> charge (m+2)^2/m^2 per output element), write V,
    // read V and U for the multiply (already charged), write M, read M,
    // write output.
    let v_elems = alpha * alpha * tiles * l.in_c as u64;
    let m_elems = alpha * alpha * tiles * l.out_c as u64;
    let u_elems = alpha * alpha * l.in_c as u64 * l.out_c as u64;
    let out_elems = p.batch as u64 * out_h * out_w * l.out_c as u64;
    let transform_bytes = 4 * (2 * v_elems + 2 * m_elems + u_elems + out_elems);
    let t_transform = transform_bytes as f64 / (dev.mem_bw_gbps * 1e9)
        // Transform arithmetic is cheap but not free: ~2*alpha^2 flops/elem.
        + (2 * alpha * alpha * (v_elems + m_elems)) as f64
            / (dev.peak_gflops * 1e9 * 0.5);

    let time = batch_time + t_transform + LAUNCH_OVERHEAD_S;
    let flops = p.flops(); // normalize to direct-conv flops
    Ok(Estimate {
        gflops: flops as f64 / time / 1e9,
        time_s: time,
        flops,
        global_bytes: est.global_bytes * alpha.pow(2) + transform_bytes,
        intensity: p.intensity(),
        occupancy: est.occupancy,
        regs_per_thread: est.regs_per_thread,
        spilled: est.spilled,
        bound: est.bound,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::device_by_name;
    use crate::nn::{resnet50_layers, vgg16_layers};

    fn nano() -> DeviceSpec {
        device_by_name("r9-nano").unwrap()
    }

    fn big_3x3() -> ConvProblem {
        // A VGG-like mid layer, the Fig. 3 regime.
        ConvProblem::new(ConvLayer::same("t", 3, 1, 56, 56, 256, 256), 4)
    }

    fn est(cfg: ConvConfig) -> Estimate {
        conv_estimate(&nano(), &big_3x3(), &cfg, &GemmConfig::default()).unwrap()
    }

    /// Paper Fig. 3: the tuned tile (4x5, vec 4/2) reaches ~10x the naive
    /// kernel on the R9 Nano.
    #[test]
    fn fig3_tiled_beats_naive_by_order_of_magnitude() {
        let tuned = est(ConvConfig::tiled(4, 5, 4, 2));
        let naive = est(ConvConfig::naive());
        let speedup = tuned.gflops / naive.gflops;
        assert!(
            speedup > 5.0,
            "expected >=5x, got {speedup:.2}x ({} vs {})",
            tuned.gflops,
            naive.gflops
        );
    }

    /// Paper Fig. 3: the peak sits at a mid-size tile with vectors — not
    /// at the biggest tile (spill) and not at 1x1 (no reuse).
    #[test]
    fn fig3_peak_at_midsize_tile() {
        let peak = est(ConvConfig::tiled(4, 5, 4, 2));
        let tiny = est(ConvConfig::tiled(1, 1, 1, 1));
        let spilly = est(ConvConfig::tiled(7, 7, 4, 4));
        assert!(peak.gflops > tiny.gflops);
        assert!(peak.gflops > spilly.gflops);
        assert!(spilly.spilled);
    }

    /// Paper Fig. 3: spilled configs crater ("as little as 50 gigaflops").
    #[test]
    fn fig3_spill_cliff() {
        let peak = est(ConvConfig::tiled(4, 5, 4, 2));
        let spilled = est(ConvConfig::tiled(7, 7, 4, 4));
        assert!(spilled.gflops < peak.gflops / 4.0);
    }

    /// Winograd wins on 3x3 layers with enough channels (paper §4.1.2:
    /// flops drop to as little as 30%).
    #[test]
    fn winograd_beats_direct_on_heavy_3x3() {
        let dev = device_by_name("uhd630").unwrap();
        let p = ConvProblem::new(ConvLayer::same("t", 3, 1, 56, 56, 256, 256), 4);
        // Winograd's batched multiply leans on a well-chosen GEMM config
        // (paper §4.1.2 last paragraph).
        let gemm_cfg = GemmConfig::parse("8x4_8x16_loc").unwrap();
        let wino = conv_estimate(&dev, &p, &ConvConfig::winograd(2),
                                 &gemm_cfg).unwrap();
        let direct = conv_estimate(&dev, &p, &ConvConfig::tiled(2, 2, 4, 2),
                                   &gemm_cfg).unwrap();
        assert!(
            wino.gflops > direct.gflops,
            "wino {} vs direct {}", wino.gflops, direct.gflops
        );
    }

    /// im2col is the right call for pointwise layers (pure GEMM), and the
    /// model must charge no patch-materialization there.
    #[test]
    fn pointwise_im2col_has_no_patch_cost() {
        let dev = device_by_name("uhd630").unwrap();
        let l = ConvLayer::same("pw", 1, 1, 28, 28, 256, 512);
        let p = ConvProblem::new(l.clone(), 4);
        let e = conv_estimate(&dev, &p, &ConvConfig::im2col(),
                              &GemmConfig::default()).unwrap();
        // Traffic equals the plain GEMM traffic: no patch term added.
        let (m, n, k) = l.im2col_gemm(4);
        let g = crate::perfmodel::gemm_estimate(
            &dev, GemmProblem::new(m, n, k), &GemmConfig::default())
            .unwrap();
        assert_eq!(e.global_bytes, g.global_bytes);
    }

    /// Every algorithm respects its domain on every device.
    #[test]
    fn algorithm_domains_enforced() {
        for dev in crate::device::all_devices() {
            let p = ConvProblem::new(ConvLayer::same("pw", 1, 1, 28, 28, 64, 64), 1);
            assert!(conv_estimate(&dev, &p, &ConvConfig::winograd(2),
                                  &GemmConfig::default()).is_err());
        }
    }

    /// Sanity: all Table 3/4 layers produce finite positive estimates
    /// with the default tiled config on every device.
    #[test]
    fn all_layers_all_devices_finite() {
        let cfg = ConvConfig::tiled(2, 2, 1, 1);
        for dev in crate::device::all_devices() {
            for l in vgg16_layers().into_iter().chain(resnet50_layers()) {
                let p = ConvProblem::new(l, 1);
                let e = conv_estimate(&dev, &p, &cfg, &GemmConfig::default())
                    .unwrap();
                assert!(e.gflops.is_finite() && e.gflops > 0.0);
                assert!(e.gflops <= dev.peak_gflops);
            }
        }
    }
}
