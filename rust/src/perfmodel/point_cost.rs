//! Per-point cost queries for the *measured* host spaces — the model
//! half of guided search.
//!
//! The zoo models ([`super::gemm_model`] / [`super::conv_model`])
//! predict absolute throughput for a `DeviceSpec`; these functions
//! answer the much weaker question guided search actually needs:
//! *relative* cost of one measured-space point against another on the
//! executing host, from the same first-principles ingredients — Eq. 3
//! register-tile reuse ([`super::reuse::register_tile_reuse`]), blocked
//! global traffic ([`super::reuse::gemm_global_traffic`]), halo-tile
//! input reuse, and the Fig. 2 register-pressure estimate.  Lower is
//! predicted-faster; only the *ordering* matters, so the unit is an
//! arbitrary "cost per useful flop".
//!
//! The one axis the model knows nothing about — the micro-kernel ISA —
//! is deliberately absent from both functions: points differing only
//! along it cost exactly the same, so `GuidedSearch`'s stable ranking
//! keeps every ISA variant of a promising blocking together instead of
//! pruning the axis it cannot see.
//!
//! The **dtype** axis is modeled: int8 elements are a quarter the bytes
//! (quarter DRAM traffic, 4× more of a panel fits in L1) and pack 4×
//! more elements per SIMD lane (quarter issue cost per element), so an
//! `i8` point prices at a [`DTYPE_I8_DISCOUNT`] of its f32 twin's
//! compute and traffic terms — cheaper, never free.  The discount is a
//! pure per-dtype factor, so points differing only along *unmodeled*
//! axes still tie exactly within each dtype.
//!
//! The **pack** axis is modeled as a traffic trade: [`Pack::Ab`] writes
//! every B element once into the `nr`-interleaved panel layout
//! ([`PACK_B_WRITE_COST`]) and in exchange re-reads the B panels
//! unit-stride at [`PACK_B_STREAM_DISCOUNT`] of the strided cost — so
//! packing is predicted to pay off exactly when the B panel is re-read
//! across enough row bands to amortize the copy, and a skinny-`m`
//! problem (one band) correctly ranks `a` ahead of `ab`.
//!
//! The **threads** axis is modeled as a pure parallel-efficiency factor
//! above the engine's small-problem cutoff: `w` resolved workers divide
//! the whole cost by `1 + (w-1)·`[`PARALLEL_EFFICIENCY`] (linear with a
//! fan-out tax, never ideal), while problems at or under
//! [`SMALL_PROBLEM_FLOPS`] keep all thread variants tied — the engine
//! plans those serial, so ranking them apart would prune nothing real.
//! Because the factor depends only on `threads` (and the problem), all
//! other per-axis orderings survive unchanged within one thread count.

use crate::blas::{BlockedParams, Dtype, Pack};
use crate::config::{ConvAlgorithm, ConvConfig};
use crate::util::pool;

use super::registers::{conv_regs, ADDRESS_REGS};
use super::reuse::{gemm_global_traffic, register_tile_reuse};

/// Relative weight of one global-memory byte against one issued load,
/// per useful flop (host caches hide most traffic; ordering is all that
/// matters).
const MEM_WEIGHT: f64 = 4.0;

/// L1 working-set budget (bytes) for the packed `bm×bk` + `bk×bn`
/// panels; blockings whose panels spill it pay proportionally.
const L1_PANEL_BYTES: f64 = 32.0 * 1024.0;

/// Scalar f32 registers the host micro-kernel can keep live before the
/// compiler starts spilling accumulators (16 visible SIMD registers of
/// 4+ lanes, minus addressing overhead).
const SPILL_REGS: f64 = 64.0;

/// Issue cost of one redundant input fetch relative to one MAC in the
/// direct-conv kernels.
const CONV_LOAD_COST: f64 = 0.5;

/// Winograd input/inverse transform overhead: relative cost of one
/// transform add against one transform-domain MAC, after amortization
/// over the channel depth of the batched GEMMs (the scatter/gather
/// stages touch each tile once; the GEMMs contract every channel).
const WINO_TRANSFORM_COST: f64 = 0.1;

/// im2col patch-matrix materialization: every input element is written
/// once and re-read once through the patch matrix.
const IM2COL_PATCH_COST: f64 = 2.0;

/// Per-element cost factor of the int8 kernel family against f32: 4×
/// elements per SIMD lane quarters the issue cost, and 1-byte elements
/// quarter the DRAM traffic, so both modeled terms scale by ¼.
pub const DTYPE_I8_DISCOUNT: f64 = 0.25;

/// Cost of streaming a packed (`nr`-interleaved, unit-stride) B panel
/// relative to re-reading the strided row-major original: packed
/// re-reads hit full cache lines and never split across `nr` columns.
pub const PACK_B_STREAM_DISCOUNT: f64 = 0.6;

/// Extra writes per B element under [`Pack::Ab`]: each element is
/// copied once into the packed panel (the packed re-reads themselves
/// are the discounted stream term).
pub const PACK_B_WRITE_COST: f64 = 1.0;

/// Issue-cost factor of a GEMM-lowered conv arm under [`Pack::Ab`]:
/// the lowered GEMMs stream their packed B panels, trimming the
/// per-MAC load cost.  Modest — the conv cost has no per-problem
/// traffic term to trade against, so the axis is priced as a small
/// strict preference rather than a break-even curve.
pub const PACK_AB_CONV_DISCOUNT: f64 = 0.95;

/// Parallel efficiency of one extra worker: `w` threads are modeled as
/// a `1 + (w-1)·η` speedup — linear scaling with a fan-out tax, never
/// ideal, so more threads always cost *something* per added worker.
pub const PARALLEL_EFFICIENCY: f64 = 0.85;

/// The engine's small-problem serial cutoff (flops), mirrored here so
/// the model ties thread variants exactly where the plan ladder would
/// run them serial anyway (`runtime::NativeEngine`'s
/// `SMALL_PROBLEM_FLOP_CUTOFF`).
pub const SMALL_PROBLEM_FLOPS: f64 = 8_000_000.0;

/// The modeled speedup of `threads` on a problem of `flops` useful
/// flops: 1 at or under the cutoff (the engine plans small problems
/// serial), else the linear-efficiency curve over the resolved worker
/// count.  A pure per-`threads` factor — see the module docs.
fn thread_speedup(threads: usize, flops: f64) -> f64 {
    if flops <= SMALL_PROBLEM_FLOPS {
        return 1.0;
    }
    let w = pool::resolve_threads(threads) as f64;
    1.0 + (w - 1.0).max(0.0) * PARALLEL_EFFICIENCY
}

/// Bytes per element of one dtype (traffic and panel-fit terms).
fn dtype_bytes(dtype: Dtype) -> f64 {
    match dtype {
        Dtype::F32 => 4.0,
        Dtype::I8 => 1.0,
    }
}

/// Issue-cost factor of one dtype (elements per lane, f32-relative).
fn dtype_issue_discount(dtype: Dtype) -> f64 {
    match dtype {
        Dtype::F32 => 1.0,
        Dtype::I8 => DTYPE_I8_DISCOUNT,
    }
}

/// Predicted relative cost per useful flop of running an `m×n×k` GEMM
/// under `p` on the host with the `dtype` kernel family and the `pack`
/// operand strategy: the Eq. 3 issue term (loads per flop of the
/// `mr×nr` register tile), a register-spill penalty above the host's
/// accumulator budget, and the blocked global-traffic term with an L1
/// panel-fit penalty — the compute term discounted by the dtype's lane
/// density and the traffic terms by its element width.  `Pack::Ab`
/// trades one packed-copy write per B element against streaming the B
/// panel re-reads, and `threads` divides the whole cost by the modeled
/// parallel speedup above the small-problem cutoff.  Lower is
/// predicted-faster.  The ISA (not part of `BlockedParams`) does not
/// contribute — see the module docs.
pub fn gemm_point_cost(
    p: &BlockedParams,
    dtype: Dtype,
    pack: Pack,
    m: u64,
    n: u64,
    k: u64,
) -> f64 {
    let flops = 2.0 * (m as f64) * (n as f64) * (k as f64);
    // Eq. 3: loads per flop of the register micro-tile, discounted by
    // the dtype's elements-per-lane density.
    let issue = dtype_issue_discount(dtype)
        / register_tile_reuse(p.mr as u32, p.nr as u32);
    // Fig. 2-style register estimate: accumulators + the rank-1 update
    // operands + addressing.
    let regs =
        (p.mr * p.nr + p.mr + p.nr) as f64 + ADDRESS_REGS as f64;
    let spill = (regs / SPILL_REGS).max(1.0);
    // Blocked DRAM traffic, bytes per flop, with an L1 panel-fit
    // penalty for `bk` panels that outgrow the cache — both in the
    // dtype's element width (4× more of an i8 panel fits).
    let bytes = dtype_bytes(dtype);
    let traffic = gemm_global_traffic(
        m,
        n,
        k,
        p.bm as u64,
        p.bn as u64,
    ) as f64
        * bytes;
    // The pack trade: Ab copies each B element once into the packed
    // layout and streams the per-row-block B re-reads (the k·n·
    // row_blocks share of the traffic) at the discounted stream cost.
    let pack_adjust = match pack {
        Pack::A => 0.0,
        Pack::Ab => {
            let row_blocks = m.div_ceil(p.bm.max(1) as u64) as f64;
            let b_rereads = (k * n) as f64 * row_blocks * bytes;
            PACK_B_WRITE_COST * (k * n) as f64 * bytes
                - (1.0 - PACK_B_STREAM_DISCOUNT) * b_rereads
        }
    };
    let panel = (p.bm * p.bk + p.bk * p.bn) as f64 * bytes;
    let l1 = (panel / L1_PANEL_BYTES).max(1.0);
    let serial =
        issue * spill + MEM_WEIGHT * (l1 * traffic + pack_adjust) / flops;
    serial / thread_speedup(p.threads, flops)
}

/// Predicted relative cost per output element (in direct-MAC units) of
/// running a `window`/`stride` convolution under algorithm `config`
/// with im2col blocking `blocked`.  Covers all three §4.1 families:
///
/// * **tiled direct** — the full `window²` MACs plus redundant halo
///   fetches per output (shrinking with the tile area) and the Fig. 2
///   register-pressure penalty;
/// * **winograd** — the F(m×m, 3×3) multiplication reduction for the
///   configured `wino_m` (`(m+2)²/m²` transform-domain multiplies
///   replace the `window²` direct MACs — F(4×4) amortizes more than
///   F(2×2)), each multiply issued through the lowered batched GEMM's
///   register micro-tile (Eq. 3), plus the scatter/gather transform
///   adds (`~2·(m+2)³` per tile, amortized over its `m²` outputs);
/// * **im2col** — the full MACs plus patch materialization traffic,
///   with the lowered GEMM's Eq. 3 issue term so a good blocking ranks
///   ahead of a bad one.
///
/// Callers pass only points that would actually run their own algorithm
/// on this shape ([`crate::config::KernelSpace::applicable`] filters
/// the rest), so no fallback modeling is needed here.  The lowered-GEMM
/// ISA is deliberately unmodeled (ties); `threads` divides the whole
/// cost by the linear-efficiency speedup — the conv problem key
/// ([`crate::config::Problem::Conv`]) carries no output dims, so there
/// is no flop count to gate on, and the measured conv sweeps are all
/// far above the serial cutoff.  `Pack::Ab` discounts the lowered-GEMM issue term of
/// the im2col and Winograd arms ([`PACK_AB_CONV_DISCOUNT`]); the direct
/// kernels have no B panel, so pack is inert there
/// (`ConvPoint::validate` rejects `ab` off the GEMM-lowered
/// algorithms).  The dtype discounts the im2col arm only — `i8` points
/// are valid solely with the im2col algorithm.
pub fn conv_point_cost(
    config: &ConvConfig,
    blocked: &BlockedParams,
    dtype: Dtype,
    pack: Pack,
    window: u32,
    stride: u32,
) -> f64 {
    let w = window as f64;
    let s = stride as f64;
    let macs = w * w; // direct MACs per output element, per channel
    let pack_gain = match (pack, config.algorithm) {
        (
            Pack::Ab,
            ConvAlgorithm::Im2col | ConvAlgorithm::Winograd,
        ) => PACK_AB_CONV_DISCOUNT,
        _ => 1.0,
    };
    let serial = match config.algorithm {
        ConvAlgorithm::Winograd => {
            let wm = config.wino_m.max(2) as f64;
            let t = wm + 2.0;
            // Transform-domain multiplies per output element, issued
            // through the batched GEMM's register micro-tile.
            let issue = 1.0
                / register_tile_reuse(blocked.mr as u32, blocked.nr as u32);
            let mul = (t * t) / (wm * wm);
            // Scatter + gather adds per output element: ~2·t³ per tile
            // over its m² outputs.
            let transform = WINO_TRANSFORM_COST * 2.0 * t * t * t
                / (wm * wm);
            mul * (1.0 + issue * pack_gain) + transform
        }
        ConvAlgorithm::Naive | ConvAlgorithm::Tiled => {
            let th = config.tile_h.max(1) as f64;
            let tw = config.tile_w.max(1) as f64;
            // Halo patch fetched per tile, amortized per output.
            let patch = ((th - 1.0) * s + w) * ((tw - 1.0) * s + w);
            let fetch = patch / (th * tw);
            let regs = conv_regs(config, window) as f64;
            let spill = (regs / SPILL_REGS).max(1.0);
            (macs + CONV_LOAD_COST * fetch) * spill
        }
        ConvAlgorithm::Im2col => {
            // Both terms quarter under i8: the lowered GEMM packs 4×
            // elements per lane and the patch matrix is 1-byte
            // elements, so the whole arm takes the dtype discount.
            let issue =
                1.0 / register_tile_reuse(blocked.mr as u32, blocked.nr as u32);
            (macs * (1.0 + issue * pack_gain)
                + CONV_LOAD_COST * IM2COL_PATCH_COST)
                * dtype_issue_discount(dtype)
        }
    };
    // No cutoff gate: conv problems carry no dims (see above), and the
    // factor is pure per-`threads`, so all other orderings survive.
    let wkr = pool::resolve_threads(blocked.threads) as f64;
    serial / (1.0 + (wkr - 1.0).max(0.0) * PARALLEL_EFFICIENCY)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_cost_prefers_square_register_tiles() {
        // Eq. 3: at a fixed register count, square micro-tiles reuse
        // best, so they must rank cheaper.
        let base = BlockedParams::default();
        let square = BlockedParams { mr: 4, nr: 4, ..base };
        let skinny = BlockedParams { mr: 16, nr: 1, ..base };
        assert!(
            gemm_point_cost(&square, Dtype::F32, Pack::A, 256, 256, 256)
                < gemm_point_cost(&skinny, Dtype::F32, Pack::A, 256, 256, 256)
        );
    }

    #[test]
    fn gemm_cost_prefers_bigger_macro_tiles_until_l1_spills() {
        // Bigger bm×bn cuts panel re-reads (less DRAM traffic)...
        let tiny = BlockedParams { bm: 8, bn: 8, ..BlockedParams::default() };
        let mid = BlockedParams { bm: 64, bn: 64, ..BlockedParams::default() };
        assert!(
            gemm_point_cost(&mid, Dtype::F32, Pack::A, 512, 512, 512)
                < gemm_point_cost(&tiny, Dtype::F32, Pack::A, 512, 512, 512)
        );
        // ...but a bk panel far beyond L1 pays the spill penalty.
        let spilled = BlockedParams { bk: 4096, ..mid };
        assert!(
            gemm_point_cost(&mid, Dtype::F32, Pack::A, 512, 512, 512)
                < gemm_point_cost(&spilled, Dtype::F32, Pack::A, 512, 512, 512)
        );
    }

    #[test]
    fn gemm_cost_models_threads_above_the_cutoff() {
        // At or under the serial cutoff thread variants tie exactly —
        // the engine plans those problems serial, so ranking them apart
        // would prune nothing real.  2·128³ ≈ 4.2M flops < 8M.
        let t1 = BlockedParams { threads: 1, ..BlockedParams::default() };
        let t8 = BlockedParams { threads: 8, ..BlockedParams::default() };
        assert_eq!(
            gemm_point_cost(&t1, Dtype::F32, Pack::A, 128, 128, 128),
            gemm_point_cost(&t8, Dtype::F32, Pack::A, 128, 128, 128)
        );
        // Above it the parallel-efficiency discount kicks in: more
        // threads rank cheaper, but never at ideal linear speedup.
        let c1 = gemm_point_cost(&t1, Dtype::F32, Pack::A, 256, 256, 256);
        let c8 = gemm_point_cost(&t8, Dtype::F32, Pack::A, 256, 256, 256);
        assert!(c8 < c1, "{c8} !< {c1}");
        assert!(c8 > c1 / 8.0, "speedup must not be ideal: {c8} vs {c1}");
        // threads: 0 (auto) resolves to the host worker count.
        let t0 = BlockedParams { threads: 0, ..BlockedParams::default() };
        let c0 = gemm_point_cost(&t0, Dtype::F32, Pack::A, 256, 256, 256);
        let w = crate::util::pool::resolve_threads(0) as f64;
        assert!((c0 - c1 / (1.0 + (w - 1.0) * PARALLEL_EFFICIENCY)).abs()
            < 1e-12);
    }

    #[test]
    fn pack_ab_pays_off_when_b_panels_are_rereaded() {
        // 512³ under the default 64×64 macro-tile re-reads each B panel
        // 8×: streaming the packed copies out-earns the one packed
        // write, so `ab` must rank strictly cheaper — the tune-smoke
        // head-to-head asserts the measured counterpart.
        let p = BlockedParams::default();
        let a = gemm_point_cost(&p, Dtype::F32, Pack::A, 512, 512, 512);
        let ab = gemm_point_cost(&p, Dtype::F32, Pack::Ab, 512, 512, 512);
        assert!(ab < a, "{ab} !< {a}");
        assert!(ab > 0.0);
        // One row band (m ≤ bm): the packed copy never amortizes, so
        // the model correctly prefers the unpacked kernel.
        let a1 = gemm_point_cost(&p, Dtype::F32, Pack::A, 32, 512, 512);
        let ab1 = gemm_point_cost(&p, Dtype::F32, Pack::Ab, 32, 512, 512);
        assert!(a1 < ab1, "{a1} !< {ab1}");
        // The same trade prices the i8 family (quarter-width panels,
        // same break-even shape).
        let qa = gemm_point_cost(&p, Dtype::I8, Pack::A, 512, 512, 512);
        let qab = gemm_point_cost(&p, Dtype::I8, Pack::Ab, 512, 512, 512);
        assert!(qab < qa, "{qab} !< {qa}");
    }

    #[test]
    fn pack_ab_discounts_the_gemm_lowered_conv_arms_only() {
        let p = BlockedParams::default();
        for cfg in [ConvConfig::im2col(), ConvConfig::winograd(2)] {
            let a = conv_point_cost(&cfg, &p, Dtype::F32, Pack::A, 3, 1);
            let ab = conv_point_cost(&cfg, &p, Dtype::F32, Pack::Ab, 3, 1);
            assert!(ab < a, "{:?}: {ab} !< {a}", cfg.algorithm);
            assert!(ab > 0.0);
        }
        // The direct kernels have no B panel: pack is inert.
        let cfg = ConvConfig::tiled(2, 2, 1, 4);
        assert_eq!(
            conv_point_cost(&cfg, &p, Dtype::F32, Pack::A, 3, 1),
            conv_point_cost(&cfg, &p, Dtype::F32, Pack::Ab, 3, 1)
        );
    }

    #[test]
    fn conv_cost_models_threads_as_a_pure_factor() {
        // More threads rank cheaper (no cutoff gate — the conv problem
        // key has no dims), never at ideal speedup, and the factor is
        // pure per-`threads`, so algorithm orderings survive within one
        // thread count.
        let t1 = BlockedParams { threads: 1, ..BlockedParams::default() };
        let t8 = BlockedParams { threads: 8, ..BlockedParams::default() };
        let cfg = ConvConfig::im2col();
        let c1 = conv_point_cost(&cfg, &t1, Dtype::F32, Pack::A, 3, 1);
        let c8 = conv_point_cost(&cfg, &t8, Dtype::F32, Pack::A, 3, 1);
        assert!(c8 < c1, "{c8} !< {c1}");
        assert!(c8 > c1 / 8.0);
        let wino = ConvConfig::winograd(2);
        let w1 = conv_point_cost(&wino, &t1, Dtype::F32, Pack::A, 3, 1);
        let w8 = conv_point_cost(&wino, &t8, Dtype::F32, Pack::A, 3, 1);
        assert_eq!(w1 < c1, w8 < c8, "ordering must survive the factor");
    }

    #[test]
    fn conv_cost_ranks_winograd_cheapest_on_its_domain() {
        // On 3×3/s1 the F(2×2) reduction beats both direct and im2col.
        let blocked = BlockedParams::default();
        let wino = conv_point_cost(
            &ConvConfig::winograd(2),
            &blocked,
            Dtype::F32,
            Pack::A,
            3,
            1,
        );
        let tiled = conv_point_cost(
            &ConvConfig::tiled(2, 2, 1, 4),
            &blocked,
            Dtype::F32,
            Pack::A,
            3,
            1,
        );
        let im2col = conv_point_cost(
            &ConvConfig::im2col(),
            &blocked,
            Dtype::F32,
            Pack::A,
            3,
            1,
        );
        assert!(wino < tiled, "{wino} !< {tiled}");
        assert!(wino < im2col, "{wino} !< {im2col}");
    }

    #[test]
    fn conv_cost_ranks_the_wino_m_axis() {
        // F(4×4) replaces 144 direct MACs with 36 multiplies where
        // F(2×2) replaces 36 with 16, so at equal blocking the model
        // must rank m=4 cheaper — the axis is modeled, not a tie, and
        // both beat im2col on the 3×3/s1 domain.
        let blocked = BlockedParams::default();
        let w2 = conv_point_cost(
            &ConvConfig::winograd(2),
            &blocked,
            Dtype::F32,
            Pack::A,
            3,
            1,
        );
        let w4 = conv_point_cost(
            &ConvConfig::winograd(4),
            &blocked,
            Dtype::F32,
            Pack::A,
            3,
            1,
        );
        let im2col = conv_point_cost(
            &ConvConfig::im2col(),
            &blocked,
            Dtype::F32,
            Pack::A,
            3,
            1,
        );
        assert!(w4 < w2, "{w4} !< {w2}");
        assert!(w2 < im2col, "{w2} !< {im2col}");
    }

    #[test]
    fn conv_wino_cost_tracks_the_gemm_blocking() {
        // The transform-domain multiplies run through the lowered
        // batched GEMM, so a good register micro-tile must rank ahead
        // of a bad one — same contract as im2col.
        let good = BlockedParams::default(); // 4x8 micro-tile
        let bad = BlockedParams { mr: 1, nr: 1, ..good };
        for m in [2u32, 4] {
            let cfg = ConvConfig::winograd(m);
            assert!(
                conv_point_cost(&cfg, &good, Dtype::F32, Pack::A, 3, 1)
                    < conv_point_cost(&cfg, &bad, Dtype::F32, Pack::A, 3, 1),
                "wino_m={m}"
            );
        }
    }

    #[test]
    fn conv_cost_tiling_amortizes_the_halo() {
        // A 2×2 output tile re-fetches less halo per output than 1×1 at
        // equal register pressure class.
        let blocked = BlockedParams::default();
        let t11 = conv_point_cost(
            &ConvConfig::tiled(1, 1, 1, 1),
            &blocked,
            Dtype::F32,
            Pack::A,
            3,
            1,
        );
        let t22 = conv_point_cost(
            &ConvConfig::tiled(2, 2, 1, 1),
            &blocked,
            Dtype::F32,
            Pack::A,
            3,
            1,
        );
        assert!(t22 < t11, "{t22} !< {t11}");
    }

    #[test]
    fn dtype_axis_prices_i8_cheaper_but_never_free() {
        // int8 quarters both the issue and traffic terms, so an i8
        // point must rank strictly cheaper than its f32 twin — for
        // GEMM and for the im2col conv arm — and stay positive.
        let p = BlockedParams::default();
        let f = gemm_point_cost(&p, Dtype::F32, Pack::A, 512, 512, 512);
        let q = gemm_point_cost(&p, Dtype::I8, Pack::A, 512, 512, 512);
        assert!(q < f, "{q} !< {f}");
        assert!(q > 0.0);
        let cfg = ConvConfig::im2col();
        let cf = conv_point_cost(&cfg, &p, Dtype::F32, Pack::A, 3, 1);
        let cq = conv_point_cost(&cfg, &p, Dtype::I8, Pack::A, 3, 1);
        assert!(cq < cf, "{cq} !< {cf}");
        assert!(cq > 0.0);
    }

    #[test]
    fn modeled_factors_are_pure_so_orderings_survive() {
        // dtype, pack, and threads each price as a factor or an
        // additive term that never flips the orderings of the *other*
        // axes: within one (threads, pack) choice, the dtype discount
        // preserves blocking order; within one (threads, dtype), the
        // pack trade preserves it on a fixed problem; and the thread
        // factor cancels entirely when both sides share a count.
        let good = BlockedParams { threads: 1, ..BlockedParams::default() };
        let bad = BlockedParams { mr: 1, nr: 1, ..good };
        for dtype in Dtype::all() {
            for pack in Pack::all() {
                assert!(
                    gemm_point_cost(&good, dtype, pack, 512, 512, 512)
                        < gemm_point_cost(&bad, dtype, pack, 512, 512, 512),
                    "{dtype} {pack}"
                );
                let cfg = ConvConfig::im2col();
                assert!(
                    conv_point_cost(&cfg, &good, dtype, pack, 3, 1)
                        < conv_point_cost(&cfg, &bad, dtype, pack, 3, 1),
                    "{dtype} {pack}"
                );
            }
        }
        // The thread factor is a pure divide: scaling both sides of a
        // comparison by it cannot reorder them.
        let g8 = BlockedParams { threads: 8, ..good };
        let b8 = BlockedParams { threads: 8, ..bad };
        assert_eq!(
            gemm_point_cost(&good, Dtype::F32, Pack::A, 512, 512, 512)
                < gemm_point_cost(&bad, Dtype::F32, Pack::A, 512, 512, 512),
            gemm_point_cost(&g8, Dtype::F32, Pack::A, 512, 512, 512)
                < gemm_point_cost(&b8, Dtype::F32, Pack::A, 512, 512, 512)
        );
    }

    #[test]
    fn conv_im2col_cost_tracks_the_gemm_blocking() {
        // im2col's cost must reflect the lowered GEMM's register-tile
        // quality so guided search ranks good blockings first.
        let good = BlockedParams::default(); // 4x8 micro-tile
        let bad = BlockedParams { mr: 1, nr: 1, ..good };
        let cfg = ConvConfig::im2col();
        assert!(
            conv_point_cost(&cfg, &good, Dtype::F32, Pack::A, 3, 1)
                < conv_point_cost(&cfg, &bad, Dtype::F32, Pack::A, 3, 1)
        );
    }
}
