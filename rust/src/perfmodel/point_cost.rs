//! Per-point cost queries for the *measured* host spaces — the model
//! half of guided search.
//!
//! The zoo models ([`super::gemm_model`] / [`super::conv_model`])
//! predict absolute throughput for a `DeviceSpec`; these functions
//! answer the much weaker question guided search actually needs:
//! *relative* cost of one measured-space point against another on the
//! executing host, from the same first-principles ingredients — Eq. 3
//! register-tile reuse ([`super::reuse::register_tile_reuse`]), blocked
//! global traffic ([`super::reuse::gemm_global_traffic`]), halo-tile
//! input reuse, and the Fig. 2 register-pressure estimate.  Lower is
//! predicted-faster; only the *ordering* matters, so the unit is an
//! arbitrary "cost per useful flop".
//!
//! Axes the model knows nothing about — the micro-kernel ISA and the
//! `threads` knob — are deliberately absent from both functions: points
//! differing only along an unmodeled axis cost exactly the same, so
//! `GuidedSearch`'s stable ranking keeps every variant of a promising
//! blocking together instead of pruning the axis it cannot see.
//!
//! The **dtype** axis is modeled: int8 elements are a quarter the bytes
//! (quarter DRAM traffic, 4× more of a panel fits in L1) and pack 4×
//! more elements per SIMD lane (quarter issue cost per element), so an
//! `i8` point prices at a [`DTYPE_I8_DISCOUNT`] of its f32 twin's
//! compute and traffic terms — cheaper, never free.  The discount is a
//! pure per-dtype factor, so points differing only along *unmodeled*
//! axes still tie exactly within each dtype.

use crate::blas::{BlockedParams, Dtype};
use crate::config::{ConvAlgorithm, ConvConfig};

use super::registers::{conv_regs, ADDRESS_REGS};
use super::reuse::{gemm_global_traffic, register_tile_reuse};

/// Relative weight of one global-memory byte against one issued load,
/// per useful flop (host caches hide most traffic; ordering is all that
/// matters).
const MEM_WEIGHT: f64 = 4.0;

/// L1 working-set budget (bytes) for the packed `bm×bk` + `bk×bn`
/// panels; blockings whose panels spill it pay proportionally.
const L1_PANEL_BYTES: f64 = 32.0 * 1024.0;

/// Scalar f32 registers the host micro-kernel can keep live before the
/// compiler starts spilling accumulators (16 visible SIMD registers of
/// 4+ lanes, minus addressing overhead).
const SPILL_REGS: f64 = 64.0;

/// Issue cost of one redundant input fetch relative to one MAC in the
/// direct-conv kernels.
const CONV_LOAD_COST: f64 = 0.5;

/// Winograd input/inverse transform overhead: relative cost of one
/// transform add against one transform-domain MAC, after amortization
/// over the channel depth of the batched GEMMs (the scatter/gather
/// stages touch each tile once; the GEMMs contract every channel).
const WINO_TRANSFORM_COST: f64 = 0.1;

/// im2col patch-matrix materialization: every input element is written
/// once and re-read once through the patch matrix.
const IM2COL_PATCH_COST: f64 = 2.0;

/// Per-element cost factor of the int8 kernel family against f32: 4×
/// elements per SIMD lane quarters the issue cost, and 1-byte elements
/// quarter the DRAM traffic, so both modeled terms scale by ¼.
pub const DTYPE_I8_DISCOUNT: f64 = 0.25;

/// Bytes per element of one dtype (traffic and panel-fit terms).
fn dtype_bytes(dtype: Dtype) -> f64 {
    match dtype {
        Dtype::F32 => 4.0,
        Dtype::I8 => 1.0,
    }
}

/// Issue-cost factor of one dtype (elements per lane, f32-relative).
fn dtype_issue_discount(dtype: Dtype) -> f64 {
    match dtype {
        Dtype::F32 => 1.0,
        Dtype::I8 => DTYPE_I8_DISCOUNT,
    }
}

/// Predicted relative cost per useful flop of running an `m×n×k` GEMM
/// under `p` on the host with the `dtype` kernel family: the Eq. 3
/// issue term (loads per flop of the `mr×nr` register tile), a
/// register-spill penalty above the host's accumulator budget, and the
/// blocked global-traffic term with an L1 panel-fit penalty — the
/// compute term discounted by the dtype's lane density and the traffic
/// terms by its element width.  Lower is predicted-faster.  `threads`
/// (and the ISA, which is not part of `BlockedParams`) do not
/// contribute — see the module docs.
pub fn gemm_point_cost(
    p: &BlockedParams,
    dtype: Dtype,
    m: u64,
    n: u64,
    k: u64,
) -> f64 {
    let flops = 2.0 * (m as f64) * (n as f64) * (k as f64);
    // Eq. 3: loads per flop of the register micro-tile, discounted by
    // the dtype's elements-per-lane density.
    let issue = dtype_issue_discount(dtype)
        / register_tile_reuse(p.mr as u32, p.nr as u32);
    // Fig. 2-style register estimate: accumulators + the rank-1 update
    // operands + addressing.
    let regs =
        (p.mr * p.nr + p.mr + p.nr) as f64 + ADDRESS_REGS as f64;
    let spill = (regs / SPILL_REGS).max(1.0);
    // Blocked DRAM traffic, bytes per flop, with an L1 panel-fit
    // penalty for `bk` panels that outgrow the cache — both in the
    // dtype's element width (4× more of an i8 panel fits).
    let bytes = dtype_bytes(dtype);
    let traffic = gemm_global_traffic(
        m,
        n,
        k,
        p.bm as u64,
        p.bn as u64,
    ) as f64
        * bytes;
    let panel = (p.bm * p.bk + p.bk * p.bn) as f64 * bytes;
    let l1 = (panel / L1_PANEL_BYTES).max(1.0);
    issue * spill + MEM_WEIGHT * l1 * traffic / flops
}

/// Predicted relative cost per output element (in direct-MAC units) of
/// running a `window`/`stride` convolution under algorithm `config`
/// with im2col blocking `blocked`.  Covers all three §4.1 families:
///
/// * **tiled direct** — the full `window²` MACs plus redundant halo
///   fetches per output (shrinking with the tile area) and the Fig. 2
///   register-pressure penalty;
/// * **winograd** — the F(m×m, 3×3) multiplication reduction for the
///   configured `wino_m` (`(m+2)²/m²` transform-domain multiplies
///   replace the `window²` direct MACs — F(4×4) amortizes more than
///   F(2×2)), each multiply issued through the lowered batched GEMM's
///   register micro-tile (Eq. 3), plus the scatter/gather transform
///   adds (`~2·(m+2)³` per tile, amortized over its `m²` outputs);
/// * **im2col** — the full MACs plus patch materialization traffic,
///   with the lowered GEMM's Eq. 3 issue term so a good blocking ranks
///   ahead of a bad one.
///
/// Callers pass only points that would actually run their own algorithm
/// on this shape ([`crate::config::KernelSpace::applicable`] filters
/// the rest), so no fallback modeling is needed here.  `threads` and
/// the lowered-GEMM ISA are deliberately unmodeled (ties).  The dtype
/// discounts the im2col arm only — `i8` points are valid solely with
/// the im2col algorithm (`ConvPoint::validate` rejects the rest), so
/// the direct and Winograd arms ignore it.
pub fn conv_point_cost(
    config: &ConvConfig,
    blocked: &BlockedParams,
    dtype: Dtype,
    window: u32,
    stride: u32,
) -> f64 {
    let w = window as f64;
    let s = stride as f64;
    let macs = w * w; // direct MACs per output element, per channel
    match config.algorithm {
        ConvAlgorithm::Winograd => {
            let wm = config.wino_m.max(2) as f64;
            let t = wm + 2.0;
            // Transform-domain multiplies per output element, issued
            // through the batched GEMM's register micro-tile.
            let issue = 1.0
                / register_tile_reuse(blocked.mr as u32, blocked.nr as u32);
            let mul = (t * t) / (wm * wm);
            // Scatter + gather adds per output element: ~2·t³ per tile
            // over its m² outputs.
            let transform = WINO_TRANSFORM_COST * 2.0 * t * t * t
                / (wm * wm);
            mul * (1.0 + issue) + transform
        }
        ConvAlgorithm::Naive | ConvAlgorithm::Tiled => {
            let th = config.tile_h.max(1) as f64;
            let tw = config.tile_w.max(1) as f64;
            // Halo patch fetched per tile, amortized per output.
            let patch = ((th - 1.0) * s + w) * ((tw - 1.0) * s + w);
            let fetch = patch / (th * tw);
            let regs = conv_regs(config, window) as f64;
            let spill = (regs / SPILL_REGS).max(1.0);
            (macs + CONV_LOAD_COST * fetch) * spill
        }
        ConvAlgorithm::Im2col => {
            // Both terms quarter under i8: the lowered GEMM packs 4×
            // elements per lane and the patch matrix is 1-byte
            // elements, so the whole arm takes the dtype discount.
            let issue =
                1.0 / register_tile_reuse(blocked.mr as u32, blocked.nr as u32);
            (macs * (1.0 + issue) + CONV_LOAD_COST * IM2COL_PATCH_COST)
                * dtype_issue_discount(dtype)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_cost_prefers_square_register_tiles() {
        // Eq. 3: at a fixed register count, square micro-tiles reuse
        // best, so they must rank cheaper.
        let base = BlockedParams::default();
        let square = BlockedParams { mr: 4, nr: 4, ..base };
        let skinny = BlockedParams { mr: 16, nr: 1, ..base };
        assert!(
            gemm_point_cost(&square, Dtype::F32, 256, 256, 256)
                < gemm_point_cost(&skinny, Dtype::F32, 256, 256, 256)
        );
    }

    #[test]
    fn gemm_cost_prefers_bigger_macro_tiles_until_l1_spills() {
        // Bigger bm×bn cuts panel re-reads (less DRAM traffic)...
        let tiny = BlockedParams { bm: 8, bn: 8, ..BlockedParams::default() };
        let mid = BlockedParams { bm: 64, bn: 64, ..BlockedParams::default() };
        assert!(
            gemm_point_cost(&mid, Dtype::F32, 512, 512, 512)
                < gemm_point_cost(&tiny, Dtype::F32, 512, 512, 512)
        );
        // ...but a bk panel far beyond L1 pays the spill penalty.
        let spilled = BlockedParams { bk: 4096, ..mid };
        assert!(
            gemm_point_cost(&mid, Dtype::F32, 512, 512, 512)
                < gemm_point_cost(&spilled, Dtype::F32, 512, 512, 512)
        );
    }

    #[test]
    fn gemm_cost_ignores_threads() {
        // The threads knob is unmodeled: variants must tie exactly so
        // guided search keeps them together (conservative ranking).
        let a = BlockedParams { threads: 1, ..BlockedParams::default() };
        let b = BlockedParams { threads: 8, ..BlockedParams::default() };
        assert_eq!(
            gemm_point_cost(&a, Dtype::F32, 128, 128, 128),
            gemm_point_cost(&b, Dtype::F32, 128, 128, 128)
        );
    }

    #[test]
    fn conv_cost_ranks_winograd_cheapest_on_its_domain() {
        // On 3×3/s1 the F(2×2) reduction beats both direct and im2col.
        let blocked = BlockedParams::default();
        let wino =
            conv_point_cost(&ConvConfig::winograd(2), &blocked, Dtype::F32, 3, 1);
        let tiled = conv_point_cost(
            &ConvConfig::tiled(2, 2, 1, 4),
            &blocked,
            Dtype::F32,
            3,
            1,
        );
        let im2col =
            conv_point_cost(&ConvConfig::im2col(), &blocked, Dtype::F32, 3, 1);
        assert!(wino < tiled, "{wino} !< {tiled}");
        assert!(wino < im2col, "{wino} !< {im2col}");
    }

    #[test]
    fn conv_cost_ranks_the_wino_m_axis() {
        // F(4×4) replaces 144 direct MACs with 36 multiplies where
        // F(2×2) replaces 36 with 16, so at equal blocking the model
        // must rank m=4 cheaper — the axis is modeled, not a tie, and
        // both beat im2col on the 3×3/s1 domain.
        let blocked = BlockedParams::default();
        let w2 =
            conv_point_cost(&ConvConfig::winograd(2), &blocked, Dtype::F32, 3, 1);
        let w4 =
            conv_point_cost(&ConvConfig::winograd(4), &blocked, Dtype::F32, 3, 1);
        let im2col =
            conv_point_cost(&ConvConfig::im2col(), &blocked, Dtype::F32, 3, 1);
        assert!(w4 < w2, "{w4} !< {w2}");
        assert!(w2 < im2col, "{w2} !< {im2col}");
    }

    #[test]
    fn conv_wino_cost_tracks_the_gemm_blocking() {
        // The transform-domain multiplies run through the lowered
        // batched GEMM, so a good register micro-tile must rank ahead
        // of a bad one — same contract as im2col.
        let good = BlockedParams::default(); // 4x8 micro-tile
        let bad = BlockedParams { mr: 1, nr: 1, ..good };
        for m in [2u32, 4] {
            let cfg = ConvConfig::winograd(m);
            assert!(
                conv_point_cost(&cfg, &good, Dtype::F32, 3, 1)
                    < conv_point_cost(&cfg, &bad, Dtype::F32, 3, 1),
                "wino_m={m}"
            );
        }
    }

    #[test]
    fn conv_cost_tiling_amortizes_the_halo() {
        // A 2×2 output tile re-fetches less halo per output than 1×1 at
        // equal register pressure class.
        let blocked = BlockedParams::default();
        let t11 = conv_point_cost(
            &ConvConfig::tiled(1, 1, 1, 1),
            &blocked,
            Dtype::F32,
            3,
            1,
        );
        let t22 = conv_point_cost(
            &ConvConfig::tiled(2, 2, 1, 1),
            &blocked,
            Dtype::F32,
            3,
            1,
        );
        assert!(t22 < t11, "{t22} !< {t11}");
    }

    #[test]
    fn dtype_axis_prices_i8_cheaper_but_never_free() {
        // int8 quarters both the issue and traffic terms, so an i8
        // point must rank strictly cheaper than its f32 twin — for
        // GEMM and for the im2col conv arm — and stay positive.
        let p = BlockedParams::default();
        let f = gemm_point_cost(&p, Dtype::F32, 512, 512, 512);
        let q = gemm_point_cost(&p, Dtype::I8, 512, 512, 512);
        assert!(q < f, "{q} !< {f}");
        assert!(q > 0.0);
        let cfg = ConvConfig::im2col();
        let cf = conv_point_cost(&cfg, &p, Dtype::F32, 3, 1);
        let cq = conv_point_cost(&cfg, &p, Dtype::I8, 3, 1);
        assert!(cq < cf, "{cq} !< {cf}");
        assert!(cq > 0.0);
    }

    #[test]
    fn dtype_is_a_pure_factor_so_unmodeled_ties_survive() {
        // Within one dtype, threads variants still tie exactly — the
        // discount must not break the unmodeled-axis tie contract.
        for dtype in Dtype::all() {
            let a = BlockedParams { threads: 1, ..BlockedParams::default() };
            let b = BlockedParams { threads: 8, ..BlockedParams::default() };
            assert_eq!(
                gemm_point_cost(&a, dtype, 128, 128, 128),
                gemm_point_cost(&b, dtype, 128, 128, 128)
            );
            let cfg = ConvConfig::im2col();
            assert_eq!(
                conv_point_cost(&cfg, &a, dtype, 3, 1),
                conv_point_cost(&cfg, &b, dtype, 3, 1)
            );
        }
    }

    #[test]
    fn conv_im2col_cost_tracks_the_gemm_blocking() {
        // im2col's cost must reflect the lowered GEMM's register-tile
        // quality so guided search ranks good blockings first.
        let good = BlockedParams::default(); // 4x8 micro-tile
        let bad = BlockedParams { mr: 1, nr: 1, ..good };
        let cfg = ConvConfig::im2col();
        assert!(
            conv_point_cost(&cfg, &good, Dtype::F32, 3, 1)
                < conv_point_cost(&cfg, &bad, Dtype::F32, 3, 1)
        );
    }
}
