//! Modeled GEMM throughput for one (problem, configuration, device)
//! triple — the generator behind the Fig. 4 / Fig. 5 roofline sweeps.

use crate::config::GemmConfig;
use crate::device::DeviceSpec;
use crate::error::Result;

use super::memory::{
    cpu_prefers_blocked, effective_bandwidth, overlap_factor,
    vector_efficiency, Access,
};
use super::occupancy::{cu_utilization, effective_fraction, occupancy};
use super::registers::gemm_regs;
use super::reuse::gemm_global_traffic;
use super::{Bound, Estimate, CPU_SIMT_PENALTY, LAUNCH_OVERHEAD_S};

/// On-chip (local memory / L1 cache) bandwidth relative to DRAM.
const ONCHIP_BW_RATIO: f64 = 6.0;

/// One GEMM problem instance (C is M x N, contraction over K).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmProblem {
    /// Rows of A and C.
    pub m: u64,
    /// Columns of B and C.
    pub n: u64,
    /// Contraction (inner) dimension.
    pub k: u64,
}

impl GemmProblem {
    /// An M x N x K problem.
    pub fn new(m: u64, n: u64, k: u64) -> Self {
        Self { m, n, k }
    }

    /// Useful flops: 2MNK (multiply + add).
    pub fn flops(&self) -> u64 {
        2 * self.m * self.n * self.k
    }

    /// Minimum possible traffic in bytes (each operand touched once) —
    /// defines the operational intensity used as the roofline x-axis,
    /// matching the paper's "flop per byte of data read or written".
    pub fn min_bytes(&self) -> u64 {
        4 * (self.m * self.k + self.k * self.n + 2 * self.m * self.n)
    }

    /// Operational intensity, flop/byte.
    pub fn intensity(&self) -> f64 {
        self.flops() as f64 / self.min_bytes() as f64
    }
}

/// Model the throughput of `cfg` on `dev` for `p`.
///
/// Returns `Error::Infeasible` for configurations that cannot launch on
/// the device (local-memory or register-file overflow) — exactly the
/// configurations the paper's tuner discards up front.
pub fn gemm_estimate(
    dev: &DeviceSpec,
    p: GemmProblem,
    cfg: &GemmConfig,
) -> Result<Estimate> {
    let flops = p.flops();
    let bm = cfg.block_m() as u64;
    let bn = cfg.block_n() as u64;
    let wgs = p.m.div_ceil(bm) * p.n.div_ceil(bn);

    // --- registers & occupancy (§2.2.1) ---
    let regs = gemm_regs(cfg);
    let spilled = regs > dev.max_regs_per_thread;
    let local_per_wg = cfg.local_mem_bytes(dev.cache_line_elems());
    let occ = occupancy(dev, regs, cfg.work_group(), local_per_wg)?;
    let occ_frac = effective_fraction(&occ, dev, cfg.work_group(), wgs);

    // --- global traffic (§2.2.3) ---
    let bytes = 4 * gemm_global_traffic(p.m, p.n, p.k, bm, bn);
    // Spilled accumulators bounce through scratch every k-panel: one
    // store + one load of the overflow per panel step, at per-lane
    // scatter (scalar-transaction) bandwidth.
    let spill_bytes = if spilled {
        let overflow = (regs - dev.max_regs_per_thread) as u64;
        let threads = wgs * cfg.work_group() as u64;
        8 * overflow * threads * (p.k / cfg.block_k.max(1) as u64).max(1)
    } else {
        0
    };

    // --- access pattern (§2.2.2) ---
    let access = if cfg.use_local {
        Access::Coalesced // staging loads are coalesced by construction
    } else if cpu_prefers_blocked(dev) || dev.local_mem_bytes == 0 {
        // CPUs stream blocked panels through the cache, and cache-backed
        // GPUs (Mali-style, no local memory) are built to do the same —
        // the very reason the paper's `_noloc` configs exist (§2.2.3).
        Access::Coalesced
    } else {
        // Direct loads on an LDS-style GPU: the A-panel walk is strided
        // by the K pitch.
        Access::Strided {
            vec: cfg.rt_n.min(dev.native_vector_width),
            stride_bytes: (p.k * 4).min(u32::MAX as u64) as u32,
        }
    };
    let bw = effective_bandwidth(dev, access, cfg.use_local);
    let scalar_bw =
        dev.mem_bw_gbps * (4.0 / dev.cache_line_bytes as f64);
    let t_mem = bytes as f64 / (bw * 1e9)
        + spill_bytes as f64 / (scalar_bw * 1e9);

    // --- compute (§2.2.4) ---
    let vec_eff = vector_efficiency(dev, cfg.rt_n);
    let util = cu_utilization(wgs, dev.compute_units);
    // OpenCL-style work-item emulation on CPUs costs versus a native
    // JIT'd library (the paper's SYCL-on-CPU vs MKL-DNN gap, §5.3).
    let host_eff = if dev.class == crate::device::DeviceClass::Cpu {
        CPU_SIMT_PENALTY
    } else {
        1.0
    };
    let eff_peak = dev.peak_gflops * 1e9
        * occ_frac.max(0.05)
        * vec_eff
        * util.max(1e-3)
        * host_eff;
    let t_comp = flops as f64 / eff_peak;

    // --- on-chip reuse bandwidth (Eq. 3) ---
    // Every flop consumes one register-tile operand element per
    // `reuse_ratio` flops, streamed from local memory / cache.  This is
    // the ceiling that rewards square register tiles (Fig. 4b) and
    // larger tiles at high intensity (Fig. 4a).
    let onchip_bw = dev.mem_bw_gbps
        * ONCHIP_BW_RATIO
        * if cfg.use_local && dev.local_mem_bytes > 0 {
            dev.local_mem_speedup
        } else {
            1.0
        };
    let t_onchip =
        flops as f64 * 4.0 / (cfg.reuse_ratio() * onchip_bw * 1e9);

    // --- combine (bounded overlap) ---
    // Double buffering needs real local memory to prefetch into; on
    // cache-only devices it just doubles the cache footprint (§2.2.3).
    let db_effective = cfg.double_buffer
        && cfg.use_local
        && dev.local_mem_bytes > 0;
    let ov = overlap_factor(occ_frac, db_effective);
    let mut time = t_comp.max(t_mem).max(t_onchip)
        + (1.0 - ov) * t_comp.min(t_mem);
    time += LAUNCH_OVERHEAD_S;

    let bound = if util < 0.5 {
        Bound::Launch
    } else if t_mem > t_comp {
        Bound::Memory
    } else {
        Bound::Compute
    };

    Ok(Estimate {
        gflops: flops as f64 / time / 1e9,
        time_s: time,
        flops,
        global_bytes: bytes + spill_bytes,
        intensity: p.intensity(),
        occupancy: occ_frac,
        regs_per_thread: regs,
        spilled,
        bound,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::device_by_name;

    fn est(dev: &str, p: (u64, u64, u64), cfg: &str) -> Estimate {
        gemm_estimate(
            &device_by_name(dev).unwrap(),
            GemmProblem::new(p.0, p.1, p.2),
            &GemmConfig::parse(cfg).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn never_exceeds_roofline() {
        for dev in crate::device::all_devices() {
            for cfg in GemmConfig::table2() {
                for &(m, n, k) in
                    &[(64, 64, 64), (512, 512, 512), (1024, 64, 1024)]
                {
                    let p = GemmProblem::new(m, n, k);
                    if let Ok(e) = gemm_estimate(&dev, p, &cfg) {
                        assert!(
                            e.gflops <= dev.roofline_gflops(e.intensity) * 1.001,
                            "{} {} {:?}: {} > roofline",
                            dev.id, cfg.name(), (m, n, k), e.gflops
                        );
                    }
                }
            }
        }
    }

    /// Paper Fig. 4a: on the Intel GPU, 8x4_8x16_loc beats 4x4_8x8_loc at
    /// high intensity ("increasing the number of registers from 4x4 to
    /// 8x4 per thread significantly improves performance").
    #[test]
    fn fig4a_bigger_register_tile_wins_at_high_intensity() {
        let big = est("uhd630", (1024, 1024, 1024), "8x4_8x16_loc");
        let small = est("uhd630", (1024, 1024, 1024), "4x4_8x8_loc");
        assert!(big.gflops > small.gflops);
    }

    /// Paper Fig. 4b: square register tile beats non-square at equal
    /// register count.
    #[test]
    fn fig4b_square_beats_nonsquare() {
        let sq = est("uhd630", (512, 512, 512), "4x4_8x8_loc");
        let ns = est("uhd630", (512, 512, 512), "8x2_4x16_loc");
        assert!(sq.gflops > ns.gflops, "{} vs {}", sq.gflops, ns.gflops);
    }

    /// Paper Fig. 4c: double buffering improves throughput.
    #[test]
    fn fig4c_double_buffering_helps() {
        let db = est("uhd630", (512, 512, 512), "8x4_8x16_loc_db");
        let nodb = est("uhd630", (512, 512, 512), "8x4_8x16_loc");
        assert!(db.gflops > nodb.gflops);
    }

    /// Paper Fig. 5 region A: small matrices favour small blocks (more
    /// work-groups, better utilization).
    #[test]
    fn fig5_region_a_small_matrices_prefer_small_blocks() {
        let small_cfg = est("mali-g71", (64, 64, 64), "4x4_8x8_noloc");
        let big_cfg = est("mali-g71", (64, 64, 64), "8x4_8x16_noloc");
        assert!(
            small_cfg.gflops > big_cfg.gflops,
            "{} vs {}", small_cfg.gflops, big_cfg.gflops
        );
    }

    /// Paper Fig. 5 region C: large matrices favour the bigger macro-tile.
    #[test]
    fn fig5_region_c_large_matrices_prefer_big_blocks() {
        let big_cfg = est("mali-g71", (1024, 1024, 1024), "8x4_8x16_noloc");
        let small_cfg = est("mali-g71", (1024, 1024, 1024), "4x4_8x8_noloc");
        assert!(big_cfg.gflops > small_cfg.gflops);
    }

    /// On Mali (no local memory), `_loc` staging costs; `_noloc` is the
    /// right choice (paper §2.2.3).
    #[test]
    fn mali_prefers_noloc() {
        let loc = est("mali-g71", (512, 512, 512), "8x4_4x8_loc");
        let noloc = est("mali-g71", (512, 512, 512), "8x4_4x8_noloc");
        assert!(noloc.gflops > loc.gflops);
    }

    #[test]
    fn spill_causes_cliff() {
        // A pathological 16x16 register tile spills everywhere.
        let huge = GemmConfig::parse("16x16_8x8_noloc").unwrap();
        let sane = GemmConfig::parse("8x4_8x16_noloc").unwrap();
        let dev = device_by_name("r9-nano").unwrap();
        let p = GemmProblem::new(1024, 1024, 1024);
        let h = gemm_estimate(&dev, p, &huge).unwrap();
        let s = gemm_estimate(&dev, p, &sane).unwrap();
        assert!(h.spilled && !s.spilled);
        assert!(h.gflops < s.gflops / 2.0, "{} vs {}", h.gflops, s.gflops);
    }

    #[test]
    fn local_overflow_infeasible_on_r9() {
        // 32 KiB LDS: a config needing more must be rejected.
        let dev = device_by_name("r9-nano").unwrap();
        let cfg = GemmConfig {
            rt_m: 8, rt_n: 8, wg_r: 16, wg_c: 16,
            use_local: true, double_buffer: true,
            ..Default::default()
        };
        assert!(cfg.local_mem_bytes(dev.cache_line_elems()) > 32 * 1024);
        assert!(gemm_estimate(&dev, GemmProblem::new(512, 512, 512), &cfg)
            .is_err());
    }

    #[test]
    fn monotone_in_device_capability() {
        // Doubling bandwidth or peak never lowers modeled throughput.
        let p = GemmProblem::new(512, 512, 512);
        let cfg = GemmConfig::parse("8x4_8x16_loc").unwrap();
        let base = device_by_name("uhd630").unwrap();
        let mut fast = base.clone();
        fast.mem_bw_gbps *= 2.0;
        let mut strong = base.clone();
        strong.peak_gflops *= 2.0;
        let e0 = gemm_estimate(&base, p, &cfg).unwrap().gflops;
        assert!(gemm_estimate(&fast, p, &cfg).unwrap().gflops >= e0);
        assert!(gemm_estimate(&strong, p, &cfg).unwrap().gflops >= e0);
    }
}
