//! Memory-transaction model (paper §2.2.2) and effective bandwidth.

use crate::device::{DeviceClass, DeviceSpec};

/// Access pattern of a kernel's dominant global loads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    /// Work-group threads read adjacent elements: every fetched cache
    /// line is fully used.
    Coalesced,
    /// Threads read `vec` contiguous elements each, but consecutive
    /// threads are `stride_bytes` apart: lines are partially used.
    Strided { vec: u32, stride_bytes: u32 },
}

/// Fraction of each fetched cache line that carries useful data
/// (paper §2.2.2: "loading a block of data will reduce the number of
/// memory transactions").
pub fn line_utilization(dev: &DeviceSpec, access: Access) -> f64 {
    match access {
        Access::Coalesced => 1.0,
        Access::Strided { vec, stride_bytes } => {
            let useful = (vec * 4).min(dev.cache_line_bytes) as f64;
            let span = stride_bytes.max(vec * 4) as f64;
            if span <= dev.cache_line_bytes as f64 {
                // Several threads' elements share a line.
                1.0
            } else {
                useful / dev.cache_line_bytes as f64
            }
        }
    }
}

/// Effective global bandwidth for a kernel, GB/s.
///
/// * `access` — the dominant load pattern;
/// * `through_local` — panels staged via local memory (coalesced staging
///   loads; on devices with *no* local memory the staging writes compete
///   with the cache, costing `local_mem_speedup < 1` as the paper notes
///   for Mali G-71).
pub fn effective_bandwidth(
    dev: &DeviceSpec,
    access: Access,
    through_local: bool,
) -> f64 {
    let base = dev.mem_bw_gbps;
    if through_local {
        if dev.local_mem_bytes == 0 {
            // "For such devices using local memory can be costly" (§2.2.3).
            base * dev.local_mem_speedup.min(1.0)
        } else {
            // Staging loads are coalesced by construction.
            base
        }
    } else {
        base * line_utilization(dev, access)
    }
}

/// Vector-unit efficiency (paper §2.2.4): how much of peak ALU throughput
/// a kernel with `vec`-wide operations extracts.
///
/// * Devices with vector ALUs want `vec == native_vector_width`; narrower
///   vectors idle lanes (floored at scalar issue, 1/width).
/// * Devices with scalar-per-lane ALUs (GCN) get full throughput at any
///   width; wider vectors only add instruction-level parallelism, which
///   matters when occupancy is low (handled by the caller).
pub fn vector_efficiency(dev: &DeviceSpec, vec: u32) -> f64 {
    if !dev.has_vector_math {
        return 1.0;
    }
    let w = dev.native_vector_width as f64;
    (vec.min(dev.native_vector_width) as f64 / w).max(1.0 / w)
}

/// Overlap of compute and memory phases, 0..=1.  Double buffering
/// (paper §3.1.2 "software pre-fetching") approaches full overlap; without
/// it, overlap degrades with occupancy (fewer resident threads to switch
/// to while a load is in flight).
pub fn overlap_factor(occupancy_fraction: f64, double_buffer: bool) -> f64 {
    if double_buffer {
        0.95
    } else {
        0.45 + 0.40 * occupancy_fraction.clamp(0.0, 1.0)
    }
}

/// CPU-class devices prefer blocked accesses over GPU-style interleaved
/// coalescing (paper §3.1.1 last paragraph): a GPU-coalesced layout costs
/// them cache-line splits, a blocked layout is free.
pub fn cpu_prefers_blocked(dev: &DeviceSpec) -> bool {
    dev.class == DeviceClass::Cpu
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::device_by_name;

    #[test]
    fn coalesced_uses_full_lines() {
        let dev = device_by_name("r9-nano").unwrap();
        assert_eq!(line_utilization(&dev, Access::Coalesced), 1.0);
    }

    #[test]
    fn scattered_scalar_wastes_lines() {
        let dev = device_by_name("r9-nano").unwrap(); // 128-byte lines
        let u = line_utilization(
            &dev,
            Access::Strided { vec: 1, stride_bytes: 512 },
        );
        assert!((u - 4.0 / 128.0).abs() < 1e-12);
        // Wider vectors recover utilization.
        let u4 = line_utilization(
            &dev,
            Access::Strided { vec: 4, stride_bytes: 512 },
        );
        assert!(u4 > u);
    }

    #[test]
    fn local_staging_on_maliless_device_costs() {
        let mali = device_by_name("mali-g71").unwrap();
        let bw_local = effective_bandwidth(&mali, Access::Coalesced, true);
        let bw_direct = effective_bandwidth(&mali, Access::Coalesced, false);
        assert!(bw_local < bw_direct, "local staging must cost on Mali");
    }

    #[test]
    fn local_staging_on_gpu_with_lds_is_free() {
        let amd = device_by_name("r9-nano").unwrap();
        let bw_local = effective_bandwidth(&amd, Access::Coalesced, true);
        assert_eq!(bw_local, amd.mem_bw_gbps);
    }

    #[test]
    fn vector_efficiency_saturates_at_native_width() {
        let intel = device_by_name("uhd630").unwrap(); // native 4
        assert!(vector_efficiency(&intel, 1) < vector_efficiency(&intel, 4));
        assert_eq!(vector_efficiency(&intel, 4), vector_efficiency(&intel, 8));
        let amd = device_by_name("r9-nano").unwrap(); // scalar-per-lane
        assert_eq!(vector_efficiency(&amd, 1), 1.0);
    }

    #[test]
    fn double_buffering_always_helps_overlap() {
        for occ in [0.0, 0.3, 0.7, 1.0] {
            assert!(overlap_factor(occ, true) > overlap_factor(occ, false));
        }
        // And overlap grows with occupancy.
        assert!(overlap_factor(0.9, false) > overlap_factor(0.1, false));
    }
}
