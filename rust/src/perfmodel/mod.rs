//! Analytic performance model — the simulated stand-in for the paper's
//! OpenCL device zoo (DESIGN.md §2, substitution 1).
//!
//! The model implements, from first principles, exactly the four
//! performance metrics the paper's §2.2 says govern kernel performance on
//! all of its devices:
//!
//! 1. **Thread reusability / occupancy** (§2.2.1) — [`occupancy`]:
//!    resident-thread limits from register file, local memory, and
//!    hardware thread slots; work-group tail quantization over compute
//!    units.
//! 2. **Memory transactions** (§2.2.2) — [`memory`]: cache-line
//!    granularity and coalescing efficiency of each access pattern.
//! 3. **Data reusability** (§2.2.3) — [`reuse`]: the blocked-GEMM traffic
//!    equations and Eq. 3's register-tile reuse ratio.
//! 4. **Vectorization** (§2.2.4) — vector-width efficiency per device
//!    class.
//!
//! [`gemm`](gemm_model) and [`conv`](conv_model) combine these into a
//! bounded-overlap roofline estimate; [`vendor`] provides the calibrated
//! hand-tuned-library curves the paper compares against.

pub mod conv_model;
pub mod gemm_model;
pub mod memory;
pub mod occupancy;
pub mod point_cost;
pub mod registers;
pub mod reuse;
pub mod vendor;

pub use conv_model::{conv_estimate, ConvProblem};
pub use gemm_model::{gemm_estimate, GemmProblem};
pub use occupancy::{occupancy, Occupancy};
pub use point_cost::{
    conv_point_cost, gemm_point_cost, DTYPE_I8_DISCOUNT,
    PACK_AB_CONV_DISCOUNT, PACK_B_STREAM_DISCOUNT, PACK_B_WRITE_COST,
    PARALLEL_EFFICIENCY, SMALL_PROBLEM_FLOPS,
};
pub use registers::{conv_regs, gemm_regs};
pub use vendor::{vendor_conv, vendor_gemm, VendorLib};


/// Which roofline ceiling binds the estimate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// ALU throughput bound (possibly occupancy-degraded).
    Compute,
    /// Global-memory bandwidth bound.
    Memory,
    /// Launch/underutilization bound (too few work-groups).
    Launch,
}

/// One modeled kernel execution.
#[derive(Debug, Clone)]
pub struct Estimate {
    /// Modeled throughput in GFLOP/s.
    pub gflops: f64,
    /// Modeled wall time in seconds.
    pub time_s: f64,
    /// Useful floating-point operations.
    pub flops: u64,
    /// Modeled global-memory traffic in bytes.
    pub global_bytes: u64,
    /// Operational intensity (flop/byte) — the roofline x-axis of
    /// paper Figs. 4 & 5.
    pub intensity: f64,
    /// Occupancy fraction achieved (0..=1).
    pub occupancy: f64,
    /// Registers per thread the configuration needs.
    pub regs_per_thread: u32,
    /// Whether the register budget was exceeded (the Fig. 3 cliff).
    pub spilled: bool,
    /// Which ceiling binds.
    pub bound: Bound,
}

impl Estimate {
    /// Fraction of the device's roofline this estimate attains at its
    /// operational intensity.
    pub fn roofline_fraction(&self, dev: &crate::device::DeviceSpec) -> f64 {
        self.gflops / dev.roofline_gflops(self.intensity)
    }
}

/// Fixed kernel-launch overhead (driver + scheduling), seconds.  One value
/// for all modeled GPU-class devices; measured hosts use real timings.
pub const LAUNCH_OVERHEAD_S: f64 = 8e-6;

/// Fraction of peak an OpenCL/SYCL work-item model extracts on a CPU
/// relative to a native JIT'd library.  Calibrated to the paper's §5.3
/// observation (SYCL-DNN max 244 GF vs MKL-DNN 366 GF on the i7-6700K).
pub const CPU_SIMT_PENALTY: f64 = 0.55;
