//! VGG-16 distinct convolution layers — paper Table 3, verbatim.

use super::layer::ConvLayer;

/// The nine distinct VGG-16 convolution shapes benchmarked in the paper
/// (Figs. 8 & 9).  All are 3x3 stride-1 SAME convolutions.
pub fn vgg16_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer::same("conv1_1", 3, 1, 224, 224, 3, 64),
        ConvLayer::same("conv1_2", 3, 1, 224, 224, 64, 64),
        ConvLayer::same("conv2_1", 3, 1, 112, 112, 64, 128),
        ConvLayer::same("conv2_2", 3, 1, 112, 112, 128, 128),
        ConvLayer::same("conv3_1", 3, 1, 56, 56, 128, 256),
        ConvLayer::same("conv3_2", 3, 1, 56, 56, 256, 256),
        ConvLayer::same("conv4_1", 3, 1, 28, 28, 256, 512),
        ConvLayer::same("conv4_2", 3, 1, 28, 28, 512, 512),
        ConvLayer::same("conv5_1", 3, 1, 14, 14, 512, 512),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_row_count_and_shapes() {
        let layers = vgg16_layers();
        assert_eq!(layers.len(), 9);
        for l in &layers {
            assert_eq!(l.window, 3);
            assert_eq!(l.stride, 1);
            assert_eq!(l.out_h(), l.in_h); // SAME s1 preserves space
        }
        let c42 = layers.iter().find(|l| l.name == "conv4_2").unwrap();
        assert_eq!((c42.in_c, c42.out_c), (512, 512));
        assert_eq!((c42.out_h(), c42.out_w()), (28, 28));
    }

    #[test]
    fn conv1_1_flops() {
        // 2 * 224^2 * 64 * 9 * 3 ≈ 0.173 GFLOP at batch 1.
        let l = &vgg16_layers()[0];
        assert_eq!(l.flops(1), 2 * 224 * 224 * 64 * 9 * 3);
    }
}
