//! ResNet-50 distinct convolution layers — paper Table 4, verbatim.

use super::layer::{ConvLayer, Padding};

/// The 26 distinct ResNet-50 convolution shapes benchmarked in the paper
/// (Figs. 6 & 7).  The stem is listed with its pre-padded 230x230 input
/// and VALID padding, exactly as Table 4 does.
pub fn resnet50_layers() -> Vec<ConvLayer> {
    let mut layers = vec![ConvLayer {
        padding: Padding::Valid,
        ..ConvLayer::same("conv1_1", 7, 2, 230, 230, 3, 64)
    }];
    let same = [
        ("conv2_1", 1, 1, 56, 56, 64, 256),
        ("conv2_2", 1, 1, 56, 56, 64, 64),
        ("conv2_3", 3, 1, 56, 56, 64, 64),
        ("conv2_4", 1, 1, 56, 56, 256, 64),
        ("conv2_5", 3, 2, 56, 56, 64, 64),
        ("conv3_1", 1, 1, 28, 28, 64, 256),
        ("conv3_2", 1, 1, 28, 28, 256, 512),
        ("conv3_3", 1, 1, 28, 28, 256, 128),
        ("conv3_4", 3, 1, 28, 28, 128, 128),
        ("conv3_5", 1, 1, 28, 28, 128, 512),
        ("conv3_6", 1, 1, 28, 28, 512, 128),
        ("conv3_7", 3, 2, 28, 28, 128, 128),
        ("conv4_1", 1, 1, 14, 14, 128, 512),
        ("conv4_2", 1, 1, 14, 14, 512, 1024),
        ("conv4_3", 1, 1, 14, 14, 512, 256),
        ("conv4_4", 3, 1, 14, 14, 256, 256),
        ("conv4_5", 1, 1, 14, 14, 256, 1024),
        ("conv4_6", 1, 1, 14, 14, 1024, 256),
        ("conv4_7", 3, 2, 14, 14, 256, 256),
        ("conv5_1", 1, 1, 7, 7, 256, 1024),
        ("conv5_2", 1, 1, 7, 7, 1024, 2048),
        ("conv5_3", 1, 1, 7, 7, 1024, 512),
        ("conv5_4", 3, 1, 7, 7, 512, 512),
        ("conv5_5", 1, 1, 7, 7, 512, 2048),
        ("conv5_6", 1, 1, 7, 7, 2048, 512),
    ];
    layers.extend(same.iter().map(|&(n, w, s, h, wd, c, k)| {
        ConvLayer::same(n, w, s, h, wd, c, k)
    }));
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_row_count() {
        assert_eq!(resnet50_layers().len(), 26);
    }

    #[test]
    fn stem_output_is_112() {
        let stem = &resnet50_layers()[0];
        assert_eq!((stem.out_h(), stem.out_w(), stem.out_c), (112, 112, 64));
    }

    #[test]
    fn downsampling_layers() {
        let layers = resnet50_layers();
        let by_name = |n: &str| layers.iter().find(|l| l.name == n).unwrap();
        assert_eq!(by_name("conv2_5").out_h(), 28);
        assert_eq!(by_name("conv3_7").out_h(), 14);
        assert_eq!(by_name("conv4_7").out_h(), 7);
    }

    #[test]
    fn pointwise_majority() {
        // 18 of 26 distinct layers are 1x1 — why ResNet is GEMM-bound
        // (paper §5.3 discussion).
        let ones = resnet50_layers()
            .iter()
            .filter(|l| l.window == 1)
            .count();
        assert_eq!(ones, 18);
    }

    #[test]
    fn matches_python_table() {
        // Spot-check the rows most load-bearing for the figures.
        let layers = resnet50_layers();
        let by_name = |n: &str| layers.iter().find(|l| l.name == n).unwrap();
        let c52 = by_name("conv5_2");
        assert_eq!((c52.in_c, c52.out_c), (1024, 2048));
        let c44 = by_name("conv4_4");
        assert_eq!((c44.window, c44.in_c, c44.out_c), (3, 256, 256));
    }
}
