//! Convolution layer specification (rows of paper Tables 3 & 4).


/// Spatial padding convention.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Padding {
    /// Output spatial size = ceil(input / stride).
    Same,
    /// No padding.
    Valid,
}

/// One 2D convolution layer: NHWC input, RSCK filter, NHWK output.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ConvLayer {
    /// Layer name as printed in the paper's tables, e.g. "conv3_2".
    pub name: String,
    /// Square window size R (= S).
    pub window: u32,
    /// Spatial stride.
    pub stride: u32,
    /// Input height.
    pub in_h: u32,
    /// Input width.
    pub in_w: u32,
    /// Input channels.
    pub in_c: u32,
    /// Output channels.
    pub out_c: u32,
    /// Padding convention.
    pub padding: Padding,
}

impl ConvLayer {
    /// Construct a SAME-padded layer (the common case in both tables).
    pub fn same(
        name: &str,
        window: u32,
        stride: u32,
        in_h: u32,
        in_w: u32,
        in_c: u32,
        out_c: u32,
    ) -> Self {
        Self {
            name: name.into(),
            window,
            stride,
            in_h,
            in_w,
            in_c,
            out_c,
            padding: Padding::Same,
        }
    }

    /// Output height under the layer's padding convention.
    pub fn out_h(&self) -> u32 {
        match self.padding {
            Padding::Same => self.in_h.div_ceil(self.stride),
            Padding::Valid => (self.in_h - self.window) / self.stride + 1,
        }
    }

    /// Output width under the layer's padding convention.
    pub fn out_w(&self) -> u32 {
        match self.padding {
            Padding::Same => self.in_w.div_ceil(self.stride),
            Padding::Valid => (self.in_w - self.window) / self.stride + 1,
        }
    }

    /// Direct-convolution flops (2 x madds), as the paper's gigaflop
    /// figures normalize.
    pub fn flops(&self, batch: u32) -> u64 {
        2 * batch as u64
            * self.out_h() as u64
            * self.out_w() as u64
            * self.out_c as u64
            * (self.window as u64).pow(2)
            * self.in_c as u64
    }

    /// Bytes touched at least once (input + filter + output), f32.
    pub fn min_bytes(&self, batch: u32) -> u64 {
        let input =
            batch as u64 * self.in_h as u64 * self.in_w as u64 * self.in_c as u64;
        let filter =
            (self.window as u64).pow(2) * self.in_c as u64 * self.out_c as u64;
        let output = batch as u64
            * self.out_h() as u64
            * self.out_w() as u64
            * self.out_c as u64;
        4 * (input + filter + output)
    }

    /// Operational intensity (flop/byte) at minimal traffic.
    pub fn intensity(&self, batch: u32) -> f64 {
        self.flops(batch) as f64 / self.min_bytes(batch) as f64
    }

    /// The GEMM this layer lowers to under im2col:
    /// `(batch*OH*OW) x (K) x (R*S*C)`.
    pub fn im2col_gemm(&self, batch: u32) -> (u64, u64, u64) {
        (
            batch as u64 * self.out_h() as u64 * self.out_w() as u64,
            self.out_c as u64,
            (self.window as u64).pow(2) * self.in_c as u64,
        )
    }

    /// Spatially scale the layer (channels intact) — used to shrink
    /// interpreter-measured variants; see python/compile/manifests.py.
    pub fn scaled_spatial(&self, max_hw: u32) -> ConvLayer {
        let mut l = self.clone();
        l.in_h = l.in_h.min(max_hw);
        l.in_w = l.in_w.min(max_hw);
        l
    }
}

impl std::fmt::Display for ConvLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {}x{}/s{} {}x{}x{} -> {}x{}x{}",
            self.name,
            self.window,
            self.window,
            self.stride,
            self.in_h,
            self.in_w,
            self.in_c,
            self.out_h(),
            self.out_w(),
            self.out_c
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_shapes() {
        let l = ConvLayer::same("t", 3, 2, 56, 56, 64, 64);
        assert_eq!((l.out_h(), l.out_w()), (28, 28));
        let l1 = ConvLayer::same("t", 3, 1, 224, 224, 3, 64);
        assert_eq!((l1.out_h(), l1.out_w()), (224, 224));
    }

    #[test]
    fn valid_padding_shapes() {
        // ResNet stem: 230x230 pre-padded input, 7x7/s2 VALID -> 112.
        let l = ConvLayer {
            padding: Padding::Valid,
            ..ConvLayer::same("stem", 7, 2, 230, 230, 3, 64)
        };
        assert_eq!((l.out_h(), l.out_w()), (112, 112));
    }

    #[test]
    fn flops_match_formula() {
        let l = ConvLayer::same("t", 3, 1, 8, 8, 4, 16);
        assert_eq!(l.flops(2), 2 * 2 * 8 * 8 * 16 * 9 * 4);
        assert_eq!(l.flops(4), 2 * l.flops(2));
    }

    #[test]
    fn im2col_gemm_dims() {
        let l = ConvLayer::same("t", 3, 1, 28, 28, 128, 256);
        assert_eq!(l.im2col_gemm(1), (28 * 28, 256, 9 * 128));
        // Pointwise: K-dim is just C.
        let p = ConvLayer::same("t", 1, 1, 28, 28, 256, 512);
        assert_eq!(p.im2col_gemm(4), (4 * 28 * 28, 512, 256));
    }
}
