//! Network layer tables: VGG-16 (paper Table 3) and ResNet-50 (Table 4).

mod layer;
mod resnet;
mod vgg;

pub use layer::{ConvLayer, Padding};
pub use resnet::resnet50_layers;
pub use vgg::vgg16_layers;

/// Both networks, keyed the way the figures are (F6/F7 = resnet,
/// F8/F9 = vgg).
pub fn network_layers(net: &str) -> crate::error::Result<Vec<ConvLayer>> {
    match net {
        "vgg" | "vgg16" => Ok(vgg16_layers()),
        "resnet" | "resnet50" => Ok(resnet50_layers()),
        other => Err(crate::error::Error::NotFound(format!(
            "network {other:?} (use vgg | resnet)"
        ))),
    }
}
