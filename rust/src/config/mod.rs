//! Kernel configuration types — the paper's template-parameter space.
//!
//! A *configuration* is one instantiation of a parametrized kernel family.
//! Tuning for a new device (the paper's headline workflow) is searching
//! this space; the types here are shared between the analytic performance
//! model, the tuner, and the artifact manifest (JSON schema kept in sync
//! with `python/compile/configs.py`).
//!
//! The [`KernelSpace`] trait is the unified face of all of it: any
//! tunable kernel family — the measured host GEMM space ([`GemmPoint`]:
//! blocking × threads × runtime-detected [`Isa`] × [`Dtype`]), the
//! measured conv space ([`ConvPoint`]: algorithm × knobs × blocking ×
//! [`Dtype`]), or the modeled
//! zoo configurations — exposes one axes/validate/encode/decode surface,
//! so the tuner's storage and sweeps and the engine's plan-time
//! resolution are written once, generically.

mod conv;
mod gemm;
mod kernel_space;
mod space;

pub use conv::{ConvAlgorithm, ConvConfig};
pub use gemm::GemmConfig;
pub use kernel_space::{ConvPoint, GemmPoint, KernelSpace, Problem};
pub use space::{
    conv_space, gemm_space, micro_kernel_shapes, ConvSpace, GemmSpace,
    MICRO_KERNEL_SHAPES,
};

/// The micro-kernel ISA axis, re-exported from [`crate::blas`] alongside
/// the registry so the whole parameter space reads from one module.
pub use crate::blas::Isa;

/// The micro-kernel precision axis, re-exported from [`crate::blas`]
/// for the same reason: `i8` points run the quantized widening-kernel
/// family, `f32` the historical one.
pub use crate::blas::Dtype;
