//! Kernel configuration types — the paper's template-parameter space.
//!
//! A *configuration* is one instantiation of a parametrized kernel family.
//! Tuning for a new device (the paper's headline workflow) is searching
//! this space; the types here are shared between the analytic performance
//! model, the tuner, and the artifact manifest (JSON schema kept in sync
//! with `python/compile/configs.py`).

mod conv;
mod gemm;
mod space;

pub use conv::{ConvAlgorithm, ConvConfig};
pub use gemm::GemmConfig;
pub use space::{
    conv_space, gemm_space, micro_kernel_shapes, ConvSpace, GemmSpace,
    MICRO_KERNEL_SHAPES,
};
