//! The unified kernel parameter space: one [`KernelSpace`] abstraction
//! for every tunable kernel family.
//!
//! The paper's core claim is that one highly parameterized kernel plus
//! per-device parameter *selection* beats per-device rewrites.  Before
//! this module, each kernel family carried its own vertical slice of
//! plumbing (its own DB variant, grid builder, sweep function, and
//! plan-time resolution arm), so every new tunable axis cost a full
//! stack of duplicated code.  A [`KernelSpace`] is a *point type* — one
//! concrete combination of kernel parameters — plus everything the
//! generic machinery needs to store, sweep, and resolve it:
//!
//! * `tuner::SelectionDb` stores any space generically (`put::<P>` /
//!   `get::<P>`), keyed by the space's `KIND` string, with per-space
//!   migration shims (`LEGACY_KINDS` + [`KernelSpace::from_legacy_json`])
//!   keeping old DB JSON loading;
//! * `tuner::tune_space_sweep` measures any space's grid through any
//!   backend, filtering points by [`KernelSpace::applicable`];
//! * `runtime::NativeEngine` resolves any plan through one generic
//!   tuned → legacy → engine-override → default ladder.
//!
//! Four spaces implement it: [`GemmPoint`] (measured host GEMM:
//! blocking × threads × **ISA** × **dtype**), [`ConvPoint`] (measured
//! host conv: algorithm × knobs × `wino_m` × blocking × **ISA** ×
//! **dtype**), and the modeled zoo configurations [`GemmConfig`] /
//! [`ConvConfig`].  The ISA axis ([`Isa`]) is the proof the abstraction
//! pays for itself: a genuinely new hardware axis wired in with no new
//! storage/sweep/resolution code — first on GEMM plans, then multiplied
//! into every 3×3 conv by the Winograd transform-domain GEMM lowering.
//! The precision axis ([`Dtype`]) repeats the trick: `i8` points run the
//! quantized widening-kernel family (`blas::int8`) under the same
//! blocking/threads/ISA knobs, with DB entries written before the axis
//! existed decoding as `f32`.  The packing axis ([`Pack`]) repeats it
//! again: `ab` points run the packed-B micro-kernel variants
//! (`nr`-interleaved B panels packed once per k-panel, reused across
//! row bands) on both measured spaces, with pre-axis entries decoding
//! as `a` — the unpacked kernels they were measured with.

use crate::blas::{native_conv_algorithm_dims, BlockedParams, Dtype, Isa, Pack};
use crate::error::{Error, Result};
use crate::util::json::Value;

use super::{ConvAlgorithm, ConvConfig, GemmConfig};

/// The problem facts point-applicability may depend on: enough to decide
/// whether a candidate can run its own kernel on this problem (shape
/// domain, e.g. Winograd's 3×3/s1) and whether the space tunes this
/// problem kind at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Problem {
    /// A GEMM problem with its dimensions.
    Gemm {
        /// Rows of C.
        m: u64,
        /// Columns of C.
        n: u64,
        /// Inner (reduction) dimension.
        k: u64,
    },
    /// A convolution problem with its domain-relevant geometry.
    Conv {
        /// Square filter window.
        window: u32,
        /// Spatial stride.
        stride: u32,
    },
}

/// One tunable kernel parameter space: a point type plus the hooks the
/// generic storage/sweep/resolution machinery needs.
///
/// Implementations are `Copy` value types; a *point* is one concrete
/// combination of every axis.  Adding a new axis to a space means
/// extending its point type and its JSON codec — the DB, the sweep, and
/// the engine ladder pick it up without modification (that is the whole
/// purpose of the abstraction; the [`Isa`] axis on [`GemmPoint`] was
/// added exactly this way).
pub trait KernelSpace: Copy + PartialEq + std::fmt::Debug {
    /// Stable kind string stored with every DB entry of this space.
    const KIND: &'static str;

    /// Legacy DB kind strings this space migrates on lookup (e.g. the
    /// pre-unification `"blocked"` entries for [`GemmPoint`]).
    const LEGACY_KINDS: &'static [&'static str];

    /// The entry field the encoded point is stored under.  The modeled
    /// zoo spaces keep their historical `"config"` field so existing DB
    /// files round-trip; new spaces use `"point"`.
    const POINT_FIELD: &'static str = "point";

    /// The axis names of this space, for docs and reports.
    fn axes() -> &'static [&'static str];

    /// The default point (what an untuned engine falls back to).
    fn default_point() -> Self;

    /// Structural validation (zero dims, unsupported enum values, ...).
    fn validate(&self) -> Result<()>;

    /// Compact configuration name for reports and DB `name` columns.
    fn point_name(&self) -> String;

    /// JSON-encode this point (the value stored under
    /// [`KernelSpace::POINT_FIELD`]).
    fn to_json(&self) -> Value;

    /// Decode a point previously written by [`KernelSpace::to_json`].
    /// Implementations validate before returning, so a successfully
    /// decoded point is always structurally sound.
    fn from_json(v: &Value) -> Result<Self>;

    /// Migration shim: decode a whole legacy DB *entry* (kind ∈
    /// [`KernelSpace::LEGACY_KINDS`]) into a point of this space.
    fn from_legacy_json(kind: &str, entry: &Value) -> Result<Self>;

    /// Whether a legacy entry of `kind` stored under problem class `op`
    /// (a `SelectionKey::op` string, e.g. `gemm_128x128x128` /
    /// `conv_3x3s1_...`) may migrate into this space.  Default:
    /// anywhere.  [`ConvPoint`] overrides it so GEMM-space entries
    /// (`blocked`, `gemm_point`) answer conv lookups only under conv
    /// problem classes — a gemm-keyed blocking is not a conv selection.
    fn legacy_kind_applies(kind: &str, op: &str) -> bool {
        let _ = (kind, op);
        true
    }

    /// Whether this point can run its own kernel on `problem` **on the
    /// executing host** — shape-domain rules (a Winograd point off its
    /// 3×3/s1 domain) and host capability (an ISA the CPU lacks) both
    /// answer `false`.  The generic sweep skips inapplicable points
    /// instead of timing fallback duplicates.
    fn applicable(&self, problem: &Problem) -> bool;

    /// Extra top-level report columns for this point's DB entry (e.g.
    /// `"algorithm"` for conv points, `"isa"` for GEMM points) so
    /// reports and CI checks read the headline axis without digging
    /// into the encoded point.
    fn report_columns(&self, entry: &mut Value) {
        let _ = entry;
    }

    /// Model-predicted relative cost of this point on `problem` (lower
    /// = predicted faster), or `None` when the space has no per-point
    /// model — the hook `tuner::GuidedSearch` ranks candidates by
    /// (through `tuner::ModelRanker`).  Two contracts keep guided
    /// pruning conservative: axes the model does not cover (ISA,
    /// `threads`) must not influence the value, so their variants tie
    /// and are kept together; and `None` means worst-rank, never
    /// dropped.  The measured host spaces answer through
    /// `perfmodel::point_cost`; the default (the modeled zoo configs,
    /// which are ranked by the full device model instead) is unmodeled.
    fn rank_hint(&self, problem: &Problem) -> Option<f64> {
        let _ = problem;
        None
    }
}

// ---- shared JSON codecs ----

/// Encode [`BlockedParams`] (shared by the gemm and conv point codecs).
pub(crate) fn blocked_to_json(p: &BlockedParams) -> Value {
    let mut o = Value::object();
    o.set("bm", p.bm)
        .set("bn", p.bn)
        .set("bk", p.bk)
        .set("mr", p.mr)
        .set("nr", p.nr)
        .set("threads", p.threads);
    o
}

/// Decode [`BlockedParams`], rejecting zero dimensions and micro-tiles
/// over the 16×16 register-kernel cap.  Absent `threads` (a pre-threads
/// DB) means "auto".
pub(crate) fn blocked_from_json(v: &Value) -> Result<BlockedParams> {
    let field = |k: &str| -> Result<usize> {
        v.get(k)
            .and_then(|x| x.as_u64())
            .map(|x| x as usize)
            .ok_or_else(|| Error::Json(format!("blocked config missing {k}")))
    };
    let p = BlockedParams {
        bm: field("bm")?,
        bn: field("bn")?,
        bk: field("bk")?,
        mr: field("mr")?,
        nr: field("nr")?,
        threads: v
            .get("threads")
            .and_then(|x| x.as_u64())
            .unwrap_or(0) as usize,
    };
    validate_blocked(&p)?;
    Ok(p)
}

/// Decode the `dtype` field of an encoded point; absent (a point
/// written before the precision axis existed) means [`Dtype::F32`].
pub(crate) fn decode_dtype(v: &Value) -> Result<Dtype> {
    match v.get("dtype").and_then(|x| x.as_str()) {
        Some(s) => s.parse::<Dtype>(),
        None => Ok(Dtype::F32),
    }
}

/// Decode the `pack` field of an encoded point; absent (a point written
/// before the packing axis existed) means [`Pack::A`] — the
/// unpacked-B kernels those DBs were measured with, so pre-axis
/// entries keep planning identically.
pub(crate) fn decode_pack(v: &Value) -> Result<Pack> {
    match v.get("pack").and_then(|x| x.as_str()) {
        Some(s) => s.parse::<Pack>(),
        None => Ok(Pack::A),
    }
}

fn validate_blocked(p: &BlockedParams) -> Result<()> {
    if p.bm == 0 || p.bn == 0 || p.bk == 0 || p.mr == 0 || p.nr == 0 {
        return Err(Error::Json(format!(
            "blocked config has a zero block dimension: {p:?}"
        )));
    }
    if p.mr > 16 || p.nr > 16 {
        return Err(Error::Json(format!(
            "blocked config exceeds the 16x16 micro-kernel cap: {p:?}"
        )));
    }
    Ok(())
}

/// Encode a [`ConvConfig`] (the historical conv/conv_native layout).
pub(crate) fn conv_to_json(c: &ConvConfig) -> Value {
    let mut o = Value::object();
    o.set("tile_h", c.tile_h)
        .set("tile_w", c.tile_w)
        .set("vec_c", c.vec_c)
        .set("vec_k", c.vec_k)
        .set("block_k", c.block_k)
        .set("algorithm", c.algorithm.as_str())
        .set("wino_m", c.wino_m);
    o
}

/// Decode a [`ConvConfig`] and validate it.
pub(crate) fn conv_from_json(v: &Value) -> Result<ConvConfig> {
    let field = |k: &str| -> Result<u32> {
        v.get(k)
            .and_then(|x| x.as_u64())
            .map(|x| x as u32)
            .ok_or_else(|| Error::Json(format!("conv config missing {k}")))
    };
    let cfg = ConvConfig {
        tile_h: field("tile_h")?,
        tile_w: field("tile_w")?,
        vec_c: field("vec_c")?,
        vec_k: field("vec_k")?,
        block_k: field("block_k")?,
        algorithm: v
            .get("algorithm")
            .and_then(|x| x.as_str())
            .ok_or_else(|| Error::Json("conv config missing algorithm".into()))?
            .parse::<ConvAlgorithm>()?,
        wino_m: field("wino_m")?,
    };
    cfg.validate()?;
    Ok(cfg)
}

// ---- GemmPoint: the measured host GEMM space ----

/// One point of the measured host GEMM space: the cache/register
/// blocking (with its `threads` knob), **the micro-kernel ISA** — the
/// runtime-detected SIMD axis — **and the dtype** — which kernel family
/// computes, the f32 one or the quantized i8×i8→i32 widening one.  This
/// is what the host sweep measures, the DB stores (kind `"gemm_point"`;
/// legacy `"blocked"` entries migrate with `isa: scalar`; points
/// written before the precision axis decode as `dtype: f32`), and GEMM
/// plans execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmPoint {
    /// Cache blocking, register micro-tile, and `threads`.
    pub params: BlockedParams,
    /// Micro-kernel instruction-set variant.
    pub isa: Isa,
    /// Micro-kernel element type (f32 or quantized int8).
    pub dtype: Dtype,
    /// Operand packing strategy: `a` packs A bands only (the
    /// historical kernel), `ab` additionally packs B into
    /// `nr`-interleaved panels reused across row bands.  Points
    /// written before the axis existed decode as `a`.
    pub pack: Pack,
}

impl Default for GemmPoint {
    fn default() -> Self {
        Self {
            params: BlockedParams::default(),
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            pack: Pack::A,
        }
    }
}

impl GemmPoint {
    /// A scalar-ISA f32 unpacked-B point over the given blocking (what
    /// every legacy `BlockedParams` API migrates to).
    pub fn scalar(params: BlockedParams) -> Self {
        Self { params, isa: Isa::Scalar, dtype: Dtype::F32, pack: Pack::A }
    }

    /// Compact name: the blocking name plus the ISA, dtype, and pack
    /// suffixes (`bm64bn64bk64_4x8_t0_avx2_i8_ab` style).
    pub fn name(&self) -> String {
        format!(
            "{}_{}_{}_{}",
            self.params.name(),
            self.isa,
            self.dtype,
            self.pack
        )
    }

    /// The point this plan can actually execute on the current host:
    /// identical if the ISA is available, otherwise degraded to
    /// [`Isa::Scalar`] (same blocking).  This is how a tuning DB written
    /// on a bigger host stays *safe* to ship everywhere — off-host
    /// entries lose only the ISA axis, never correctness.
    pub fn host_degraded(self) -> Self {
        if self.isa.is_available() {
            self
        } else {
            Self { isa: Isa::Scalar, ..self }
        }
    }
}

impl KernelSpace for GemmPoint {
    const KIND: &'static str = "gemm_point";
    const LEGACY_KINDS: &'static [&'static str] = &["blocked"];

    fn axes() -> &'static [&'static str] {
        &["bm", "bn", "bk", "mr", "nr", "threads", "isa", "dtype", "pack"]
    }

    fn default_point() -> Self {
        Self::default()
    }

    fn validate(&self) -> Result<()> {
        validate_blocked(&self.params)
    }

    fn point_name(&self) -> String {
        self.name()
    }

    fn to_json(&self) -> Value {
        let mut o = blocked_to_json(&self.params);
        o.set("isa", self.isa.as_str())
            .set("dtype", self.dtype.as_str())
            .set("pack", self.pack.as_str());
        o
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            params: blocked_from_json(v)?,
            // Absent isa (a point written before the axis existed)
            // means scalar.
            isa: match v.get("isa").and_then(|x| x.as_str()) {
                Some(s) => s.parse::<Isa>()?,
                None => Isa::Scalar,
            },
            // Absent dtype (a point written before the precision axis
            // existed) means f32, so pre-axis DBs plan identically.
            dtype: decode_dtype(v)?,
            // Absent pack means the unpacked-B kernels (pack: a).
            pack: decode_pack(v)?,
        })
    }

    fn from_legacy_json(kind: &str, entry: &Value) -> Result<Self> {
        match kind {
            // Pre-unification measured GEMM selections: the blocking
            // lives under "config", and the ISA axis did not exist.
            "blocked" => Ok(Self::scalar(blocked_from_json(
                entry.get("config").ok_or_else(|| {
                    Error::Json("blocked entry missing config".into())
                })?,
            )?)),
            other => Err(Error::Json(format!(
                "gemm_point cannot migrate kind {other:?}"
            ))),
        }
    }

    fn applicable(&self, _problem: &Problem) -> bool {
        // The blocking applies to GEMM problems directly and to conv
        // problems through the im2col lowering (the legacy blocked
        // sweep's contract); the ISA additionally requires host support.
        self.isa.is_available()
    }

    fn report_columns(&self, entry: &mut Value) {
        entry
            .set("isa", self.isa.as_str())
            .set("dtype", self.dtype.as_str())
            .set("pack", self.pack.as_str());
    }

    fn rank_hint(&self, problem: &Problem) -> Option<f64> {
        // The ISA axis is deliberately not priced: variants of one
        // blocking tie, so guided search keeps them all (conservative
        // ranking of the axis the model cannot see).  The dtype, pack,
        // and threads axes ARE priced — int8 quarters per-element
        // traffic and lane issue, `ab` trades a packed-B copy against
        // streamed panel re-reads, and `threads` earns the parallel
        // efficiency discount above the serial cutoff.
        match *problem {
            Problem::Gemm { m, n, k } => Some(
                crate::perfmodel::gemm_point_cost(
                    &self.params,
                    self.dtype,
                    self.pack,
                    m,
                    n,
                    k,
                ),
            ),
            // Under a conv key this blocking means "im2col under these
            // params" (the legacy blocked-sweep contract); the lowered
            // GEMM dims are not among the Problem facts, so rank on the
            // blocking quality at a representative cubic problem.
            Problem::Conv { .. } => Some(crate::perfmodel::gemm_point_cost(
                &self.params,
                self.dtype,
                self.pack,
                256,
                256,
                256,
            )),
        }
    }
}

// ---- ConvPoint: the measured host convolution space ----

/// One point of the measured host convolution space: the algorithm and
/// its tile/vector knobs ([`ConvConfig`], including the Winograd
/// `wino_m` tile size), the GEMM blocking the lowered-GEMM paths
/// (im2col and Winograd's transform-domain batched GEMMs) use, the
/// `threads` knob every algorithm honors, **and the micro-kernel ISA**
/// those lowered GEMMs dispatch.  Stored as kind `"conv_point"`;
/// legacy `"conv_native"` entries (and pre-algorithm `"blocked"` /
/// `"gemm_point"` conv selections, which plan as im2col) migrate on
/// lookup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvPoint {
    /// Algorithm + tile/vector configuration.
    pub config: ConvConfig,
    /// Lowered-GEMM blocking + `threads`.
    pub blocked: BlockedParams,
    /// Micro-kernel ISA of the lowered GEMM (im2col and Winograd
    /// paths; the direct kernels ignore it).
    pub isa: Isa,
    /// Element type of the lowered GEMM.  `i8` runs the quantized
    /// im2col lowering (`blas::conv2d_im2col_i8`) and is only valid
    /// with `algorithm: im2col` — Winograd's transform domain and the
    /// tiled/naive direct kernels have no quantized bodies.
    pub dtype: Dtype,
    /// Operand packing of the lowered GEMM.  `ab` is only valid with
    /// the GEMM-lowered algorithms (im2col, winograd) — the direct
    /// kernels have no B panel to pack.  Points written before the
    /// axis existed decode as `a`.
    pub pack: Pack,
}

impl Default for ConvPoint {
    fn default() -> Self {
        Self::im2col(BlockedParams::default())
    }
}

impl ConvPoint {
    /// The scalar-ISA f32 unpacked-B im2col point over the given
    /// blocking (the untuned default and the migration target for
    /// pre-algorithm conv selections).
    pub fn im2col(blocked: BlockedParams) -> Self {
        Self {
            config: ConvConfig::im2col(),
            blocked,
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            pack: Pack::A,
        }
    }

    /// Compact name for reports
    /// (`wino2_v1x1+bm64bn64bk64_4x8_t2_avx2_f32_ab` style).
    pub fn name(&self) -> String {
        format!(
            "{}+{}_{}_{}_{}",
            self.config.name(),
            self.blocked.name(),
            self.isa,
            self.dtype,
            self.pack
        )
    }

    /// The point this plan can actually execute on the current host:
    /// identical if the ISA is available, otherwise degraded to
    /// [`Isa::Scalar`] (same algorithm and blocking) — the conv side of
    /// the [`GemmPoint::host_degraded`] safety rule, so a tuning DB
    /// written on a bigger host stays safe to ship everywhere.
    pub fn host_degraded(self) -> Self {
        if self.isa.is_available() {
            self
        } else {
            Self { isa: Isa::Scalar, ..self }
        }
    }
}

impl KernelSpace for ConvPoint {
    const KIND: &'static str = "conv_point";
    const LEGACY_KINDS: &'static [&'static str] =
        &["conv_native", "blocked", "gemm_point"];

    fn axes() -> &'static [&'static str] {
        &[
            "algorithm", "tile_h", "tile_w", "vec_c", "vec_k", "block_k",
            "wino_m", "bm", "bn", "bk", "mr", "nr", "threads", "isa",
            "dtype", "pack",
        ]
    }

    fn default_point() -> Self {
        Self::default()
    }

    fn validate(&self) -> Result<()> {
        self.config.validate()?;
        validate_blocked(&self.blocked)?;
        if self.dtype == Dtype::I8
            && self.config.algorithm != ConvAlgorithm::Im2col
        {
            return Err(Error::Config(format!(
                "dtype i8 requires the im2col algorithm (no quantized \
                 {} bodies): {self:?}",
                self.config.algorithm.as_str()
            )));
        }
        if self.pack == Pack::Ab
            && !matches!(
                self.config.algorithm,
                ConvAlgorithm::Im2col | ConvAlgorithm::Winograd
            )
        {
            return Err(Error::Config(format!(
                "pack ab requires a GEMM-lowered algorithm (im2col or \
                 winograd; the direct {} kernel has no B panel): {self:?}",
                self.config.algorithm.as_str()
            )));
        }
        Ok(())
    }

    fn point_name(&self) -> String {
        self.name()
    }

    fn to_json(&self) -> Value {
        let mut o = Value::object();
        o.set("config", conv_to_json(&self.config))
            .set("blocked", blocked_to_json(&self.blocked))
            .set("isa", self.isa.as_str())
            .set("dtype", self.dtype.as_str())
            .set("pack", self.pack.as_str());
        o
    }

    fn from_json(v: &Value) -> Result<Self> {
        let p = Self {
            config: conv_from_json(v.get("config").ok_or_else(|| {
                Error::Json("conv point missing config".into())
            })?)?,
            blocked: blocked_from_json(v.get("blocked").ok_or_else(|| {
                Error::Json("conv point missing blocked".into())
            })?)?,
            // Absent isa (a point written before the conv axis existed)
            // means scalar, mirroring GemmPoint.
            isa: match v.get("isa").and_then(|x| x.as_str()) {
                Some(s) => s.parse::<Isa>()?,
                None => Isa::Scalar,
            },
            // Absent dtype means f32 (pre-axis DBs plan identically).
            dtype: decode_dtype(v)?,
            // Absent pack means the unpacked-B lowering (pack: a).
            pack: decode_pack(v)?,
        };
        // The parts validate above; the cross-field dtype/algorithm and
        // pack/algorithm rules need the whole point.
        p.validate()?;
        Ok(p)
    }

    fn from_legacy_json(kind: &str, entry: &Value) -> Result<Self> {
        match kind {
            // Pre-unification measured conv selections: config + blocked
            // at the entry's top level (no isa field → scalar).
            "conv_native" => Self::from_json(entry),
            // Pre-algorithm conv selections (plain blocking): plan as
            // im2col under those params, exactly as they always did.
            "blocked" => Ok(Self::im2col(blocked_from_json(
                entry.get("config").ok_or_else(|| {
                    Error::Json("blocked entry missing config".into())
                })?,
            )?)),
            // A unified GEMM point stored under a conv key (the legacy
            // blocked sweep run over a conv group): im2col under that
            // blocking, keeping the measured ISA — the lowered conv
            // GEMM dispatches it now.
            "gemm_point" => {
                let gp = GemmPoint::from_json(entry.get("point").ok_or_else(
                    || Error::Json("gemm_point entry missing point".into()),
                )?)?;
                // The measured ISA, dtype, *and* pack all transfer: the
                // conv plans as im2col, which is GEMM-lowered, so every
                // measured GEMM axis is executable there.
                Ok(Self {
                    isa: gp.isa,
                    dtype: gp.dtype,
                    pack: gp.pack,
                    ..Self::im2col(gp.params)
                })
            }
            other => Err(Error::Json(format!(
                "conv_point cannot migrate kind {other:?}"
            ))),
        }
    }

    fn applicable(&self, problem: &Problem) -> bool {
        match *problem {
            Problem::Gemm { .. } => false,
            // Keep only points that run their own algorithm on this
            // shape — the engine's plan-time fallback rule, verbatim, so
            // a sweep can never time a fallback duplicate the plan would
            // resolve differently — and whose lowered-GEMM ISA the
            // executing host supports.
            Problem::Conv { window, stride } => {
                self.isa.is_available()
                    && native_conv_algorithm_dims(
                        &self.config,
                        window,
                        stride,
                    ) == self.config.algorithm
            }
        }
    }

    fn legacy_kind_applies(kind: &str, op: &str) -> bool {
        match kind {
            // GEMM-space entries mean "im2col under this blocking" only
            // when they sit under a conv problem class; under a gemm
            // class they are GEMM selections and must not answer conv
            // lookups.
            "blocked" | "gemm_point" => op.starts_with("conv_"),
            _ => true,
        }
    }

    fn report_columns(&self, entry: &mut Value) {
        entry
            .set("algorithm", self.config.algorithm.as_str())
            .set("wino_m", self.config.wino_m)
            .set("isa", self.isa.as_str())
            .set("dtype", self.dtype.as_str())
            .set("pack", self.pack.as_str());
    }

    fn rank_hint(&self, problem: &Problem) -> Option<f64> {
        // The ISA is deliberately not priced (ties — see the GemmPoint
        // note); the algorithm + tile/vector knobs (including
        // `wino_m`), the lowered-GEMM blocking, the dtype, the pack
        // strategy, and the threads knob are.
        match *problem {
            Problem::Gemm { .. } => None,
            Problem::Conv { window, stride } => {
                Some(crate::perfmodel::conv_point_cost(
                    &self.config,
                    &self.blocked,
                    self.dtype,
                    self.pack,
                    window,
                    stride,
                ))
            }
        }
    }
}

// ---- the modeled zoo spaces ----

impl KernelSpace for GemmConfig {
    const KIND: &'static str = "gemm";
    const LEGACY_KINDS: &'static [&'static str] = &[];
    // Historical layout: the paper-style name string under "config".
    const POINT_FIELD: &'static str = "config";

    fn axes() -> &'static [&'static str] {
        &["rt_m", "rt_n", "wg_r", "wg_c", "block_k", "use_local",
          "double_buffer"]
    }

    fn default_point() -> Self {
        GemmConfig::default()
    }

    fn validate(&self) -> Result<()> {
        if self.rt_m == 0 || self.rt_n == 0 || self.wg_r == 0
            || self.wg_c == 0
        {
            return Err(Error::Config(format!(
                "gemm config has a zero dimension: {self:?}"
            )));
        }
        Ok(())
    }

    fn point_name(&self) -> String {
        self.name()
    }

    fn to_json(&self) -> Value {
        Value::Str(self.name())
    }

    fn from_json(v: &Value) -> Result<Self> {
        GemmConfig::parse(v.as_str().ok_or_else(|| {
            Error::Json("gemm config must be a name string".into())
        })?)
    }

    fn from_legacy_json(kind: &str, _entry: &Value) -> Result<Self> {
        Err(Error::Json(format!("gemm cannot migrate kind {kind:?}")))
    }

    fn applicable(&self, problem: &Problem) -> bool {
        matches!(problem, Problem::Gemm { .. })
    }
}

impl KernelSpace for ConvConfig {
    const KIND: &'static str = "conv";
    const LEGACY_KINDS: &'static [&'static str] = &[];
    // Historical layout: the config object under "config".
    const POINT_FIELD: &'static str = "config";

    fn axes() -> &'static [&'static str] {
        &["algorithm", "tile_h", "tile_w", "vec_c", "vec_k", "block_k",
          "wino_m"]
    }

    fn default_point() -> Self {
        ConvConfig::default()
    }

    fn validate(&self) -> Result<()> {
        ConvConfig::validate(self)
    }

    fn point_name(&self) -> String {
        self.name()
    }

    fn to_json(&self) -> Value {
        conv_to_json(self)
    }

    fn from_json(v: &Value) -> Result<Self> {
        conv_from_json(v)
    }

    fn from_legacy_json(kind: &str, _entry: &Value) -> Result<Self> {
        Err(Error::Json(format!("conv cannot migrate kind {kind:?}")))
    }

    fn applicable(&self, problem: &Problem) -> bool {
        match *problem {
            Problem::Gemm { .. } => false,
            Problem::Conv { window, stride } => {
                self.algorithm.supports(window, stride)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_point_json_roundtrip_includes_isa_and_dtype() {
        for isa in Isa::all() {
            for dtype in Dtype::all() {
                for pack in Pack::all() {
                    let p = GemmPoint {
                        params: BlockedParams {
                            bm: 32, bn: 48, bk: 8, mr: 2, nr: 4, threads: 3,
                        },
                        isa,
                        dtype,
                        pack,
                    };
                    let back = GemmPoint::from_json(&p.to_json()).unwrap();
                    assert_eq!(back, p);
                    // Name anatomy: blocking, then ISA, then dtype,
                    // then pack.
                    let want = format!("_{isa}_{dtype}_{pack}");
                    assert!(p.name().ends_with(&want), "{}", p.name());
                }
            }
        }
    }

    #[test]
    fn gemm_point_absent_isa_means_scalar() {
        // A pre-axis point (no isa, no dtype, no pack) decodes as the
        // scalar f32 unpacked point — pre-axis DBs keep planning
        // identically.
        let v = blocked_to_json(&BlockedParams::default());
        let p = GemmPoint::from_json(&v).unwrap();
        assert_eq!(p.isa, Isa::Scalar);
        assert_eq!(p.dtype, Dtype::F32);
        assert_eq!(p.pack, Pack::A);
    }

    #[test]
    fn gemm_point_legacy_blocked_migration() {
        let mut entry = Value::object();
        entry
            .set("kind", "blocked")
            .set("config", blocked_to_json(&BlockedParams::default()))
            .set("gflops", 1.0);
        let p = GemmPoint::from_legacy_json("blocked", &entry).unwrap();
        assert_eq!(p, GemmPoint::default());
        assert!(GemmPoint::from_legacy_json("conv_native", &entry).is_err());
    }

    #[test]
    fn gemm_point_rejects_bad_blocking() {
        let mut v = blocked_to_json(&BlockedParams::default());
        v.set("bm", 0u64);
        assert!(GemmPoint::from_json(&v).is_err());
        let mut v = blocked_to_json(&BlockedParams::default());
        v.set("mr", 32u64);
        assert!(GemmPoint::from_json(&v).is_err(), "over the kernel cap");
        let mut v = blocked_to_json(&BlockedParams::default());
        v.set("isa", "avx512vnni");
        assert!(GemmPoint::from_json(&v).is_err(), "unknown isa");
        let mut v = blocked_to_json(&BlockedParams::default());
        v.set("dtype", "f16");
        assert!(GemmPoint::from_json(&v).is_err(), "unknown dtype");
        let mut v = blocked_to_json(&BlockedParams::default());
        v.set("pack", "b");
        assert!(GemmPoint::from_json(&v).is_err(), "unknown pack");
    }

    #[test]
    fn host_degraded_keeps_available_isas_only() {
        for isa in Isa::all() {
            for dtype in Dtype::all() {
                let p = GemmPoint {
                    params: BlockedParams::default(),
                    isa,
                    dtype,
                    pack: Pack::Ab,
                };
                let d = p.host_degraded();
                assert!(d.isa.is_available());
                assert_eq!(d.params, p.params);
                // The ISA degrade never touches the dtype or pack axes
                // — any host can run the scalar widening i8 kernel and
                // the packed-B scalar kernel.
                assert_eq!(d.dtype, dtype);
                assert_eq!(d.pack, Pack::Ab);
                if isa.is_available() {
                    assert_eq!(d.isa, isa);
                } else {
                    assert_eq!(d.isa, Isa::Scalar);
                }
            }
        }
    }

    #[test]
    fn conv_point_json_and_legacy_migrations() {
        let blocked_params = BlockedParams {
            bm: 16, bn: 16, bk: 8, mr: 2, nr: 4, threads: 2,
        };
        for isa in Isa::all() {
            for pack in Pack::all() {
                let p = ConvPoint {
                    config: ConvConfig::winograd(4),
                    blocked: blocked_params,
                    isa,
                    dtype: Dtype::F32,
                    pack,
                };
                assert_eq!(ConvPoint::from_json(&p.to_json()).unwrap(), p);
                let want = format!("_{isa}_f32_{pack}");
                assert!(p.name().ends_with(&want), "{}", p.name());
            }
        }
        // The i8 conv point round-trips too — im2col only.
        let q = ConvPoint { dtype: Dtype::I8, ..ConvPoint::default() };
        assert_eq!(ConvPoint::from_json(&q.to_json()).unwrap(), q);
        assert!(q.name().ends_with("_i8"), "{}", q.name());
        let p = ConvPoint {
            config: ConvConfig::winograd(2),
            blocked: blocked_params,
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            pack: Pack::A,
        };

        // conv_native entries: config + blocked at the top level, no
        // isa field → scalar.
        let mut legacy = Value::object();
        legacy
            .set("kind", "conv_native")
            .set("config", conv_to_json(&p.config))
            .set("blocked", blocked_to_json(&p.blocked));
        assert_eq!(
            ConvPoint::from_legacy_json("conv_native", &legacy).unwrap(),
            p
        );

        // blocked entries: im2col under those params.
        let mut blocked = Value::object();
        blocked.set("config", blocked_to_json(&p.blocked));
        let m = ConvPoint::from_legacy_json("blocked", &blocked).unwrap();
        assert_eq!(m.config.algorithm, ConvAlgorithm::Im2col);
        assert_eq!(m.blocked, p.blocked);
        assert_eq!(m.isa, Isa::Scalar);
        assert_eq!(m.dtype, Dtype::F32);
        assert_eq!(m.pack, Pack::A);

        // gemm_point entries: im2col, measured ISA, dtype, and pack all
        // preserved (the lowered conv GEMM dispatches them now).
        let gp = GemmPoint {
            params: p.blocked,
            isa: Isa::Avx2,
            dtype: Dtype::I8,
            pack: Pack::Ab,
        };
        let mut entry = Value::object();
        entry.set("kind", "gemm_point").set("point", gp.to_json());
        let m = ConvPoint::from_legacy_json("gemm_point", &entry).unwrap();
        assert_eq!(m.config.algorithm, ConvAlgorithm::Im2col);
        assert_eq!(m.blocked, p.blocked);
        assert_eq!(m.isa, Isa::Avx2);
        assert_eq!(m.dtype, Dtype::I8);
        assert_eq!(m.pack, Pack::Ab);
    }

    #[test]
    fn conv_point_i8_requires_im2col() {
        // No quantized Winograd/tiled bodies exist; such a point must
        // fail validation and decoding.
        let p = ConvPoint {
            config: ConvConfig::winograd(2),
            blocked: BlockedParams::default(),
            isa: Isa::Scalar,
            dtype: Dtype::I8,
            pack: Pack::A,
        };
        assert!(p.validate().is_err());
        assert!(ConvPoint::from_json(&p.to_json()).is_err());
        let ok = ConvPoint { dtype: Dtype::I8, ..ConvPoint::default() };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn conv_point_pack_ab_requires_a_gemm_lowered_algorithm() {
        // The direct kernels have no B panel; `ab` must fail validation
        // and decoding there, and pass on im2col and winograd.
        for cfg in [ConvConfig::tiled(2, 2, 1, 4), ConvConfig::default()] {
            if matches!(
                cfg.algorithm,
                ConvAlgorithm::Im2col | ConvAlgorithm::Winograd
            ) {
                continue; // only exercise the direct arms here
            }
            let p = ConvPoint {
                config: cfg,
                blocked: BlockedParams::default(),
                isa: Isa::Scalar,
                dtype: Dtype::F32,
                pack: Pack::Ab,
            };
            assert!(p.validate().is_err(), "{:?}", cfg.algorithm);
            assert!(ConvPoint::from_json(&p.to_json()).is_err());
        }
        for cfg in [ConvConfig::im2col(), ConvConfig::winograd(2)] {
            let p = ConvPoint {
                config: cfg,
                blocked: BlockedParams::default(),
                isa: Isa::Scalar,
                dtype: Dtype::F32,
                pack: Pack::Ab,
            };
            assert!(p.validate().is_ok(), "{:?}", cfg.algorithm);
            assert_eq!(ConvPoint::from_json(&p.to_json()).unwrap(), p);
        }
    }

    #[test]
    fn conv_point_absent_isa_means_scalar() {
        // A point written before the conv ISA axis existed decodes as
        // scalar, so pre-axis DBs keep planning identically.
        let p = ConvPoint::default();
        let mut v = Value::object();
        v.set("config", conv_to_json(&p.config))
            .set("blocked", blocked_to_json(&p.blocked));
        let back = ConvPoint::from_json(&v).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.isa, Isa::Scalar);
        assert_eq!(back.dtype, Dtype::F32);
        assert_eq!(back.pack, Pack::A);
    }

    #[test]
    fn conv_point_host_degraded_mirrors_gemm() {
        for isa in Isa::all() {
            let p = ConvPoint {
                config: ConvConfig::winograd(4),
                blocked: BlockedParams::default(),
                isa,
                dtype: Dtype::F32,
                pack: Pack::Ab,
            };
            let d = p.host_degraded();
            assert!(d.isa.is_available());
            assert_eq!(d.config, p.config, "algorithm axes survive");
            assert_eq!(d.blocked, p.blocked);
            assert_eq!(d.pack, Pack::Ab, "the pack axis survives");
            if isa.is_available() {
                assert_eq!(d.isa, isa);
            } else {
                assert_eq!(d.isa, Isa::Scalar);
            }
        }
    }

    #[test]
    fn applicability_mirrors_the_fallback_rule() {
        let gemm = Problem::Gemm { m: 64, n: 64, k: 64 };
        let s1 = Problem::Conv { window: 3, stride: 1 };
        let s2 = Problem::Conv { window: 3, stride: 2 };

        // Conv points follow the native fallback rule exactly — for
        // both native wino_m values.
        for m in [2u32, 4] {
            let wino = ConvPoint {
                config: ConvConfig::winograd(m),
                blocked: BlockedParams::default(),
                isa: Isa::Scalar,
                dtype: Dtype::F32,
                pack: Pack::A,
            };
            assert!(wino.applicable(&s1), "wino_m={m} on-domain");
            assert!(!wino.applicable(&s2), "winograd off-domain");
            assert!(!wino.applicable(&gemm));
        }
        assert!(ConvPoint::default().applicable(&s2), "im2col anywhere");

        // The conv ISA axis requires host support, like GemmPoint's.
        if let Some(missing) =
            Isa::all().into_iter().find(|i| !i.is_available())
        {
            assert!(!ConvPoint { isa: missing, ..ConvPoint::default() }
                .applicable(&s1));
        }
        for isa in Isa::detect() {
            assert!(ConvPoint { isa, ..ConvPoint::default() }
                .applicable(&s1));
        }

        // GEMM points require host ISA support (scalar: everywhere;
        // both problem kinds, for the legacy blocked-sweep contract).
        let p = GemmPoint::default();
        assert!(p.applicable(&gemm));
        assert!(p.applicable(&s1));
        if let Some(missing) =
            Isa::all().into_iter().find(|i| !i.is_available())
        {
            assert!(!GemmPoint {
                params: BlockedParams::default(),
                isa: missing,
                dtype: Dtype::F32,
                pack: Pack::A,
            }
            .applicable(&gemm));
        }
        for isa in Isa::detect() {
            for dtype in Dtype::all() {
                for pack in Pack::all() {
                    // The dtype and pack axes never constrain GEMM
                    // applicability — every host runs the widening i8
                    // kernels and the packed-B kernels.
                    assert!(GemmPoint {
                        params: BlockedParams::default(),
                        isa,
                        dtype,
                        pack,
                    }
                    .applicable(&gemm));
                }
            }
        }
        // An i8 im2col conv point is applicable wherever f32 im2col is,
        // and so is a packed-B one.
        assert!(ConvPoint { dtype: Dtype::I8, ..ConvPoint::default() }
            .applicable(&s1));
        assert!(ConvPoint { pack: Pack::Ab, ..ConvPoint::default() }
            .applicable(&s1));
    }

    #[test]
    fn legacy_kind_gating_is_keyed_on_the_problem_class() {
        // GEMM-space entries migrate into the conv space only under
        // conv problem classes; conv_native entries are conv-keyed by
        // construction and always apply.  GemmPoint keeps its historical
        // contract of answering under both problem classes.
        for kind in ["blocked", "gemm_point"] {
            assert!(ConvPoint::legacy_kind_applies(kind, "conv_3x3s1_x"));
            assert!(!ConvPoint::legacy_kind_applies(kind, "gemm_64x64x64"));
        }
        assert!(ConvPoint::legacy_kind_applies("conv_native", "conv_3x3s1_x"));
        assert!(GemmPoint::legacy_kind_applies("blocked", "gemm_64x64x64"));
        assert!(GemmPoint::legacy_kind_applies("blocked", "conv_3x3s1_x"));
    }

    #[test]
    fn rank_hints_tie_across_unmodeled_axes() {
        // 128³ sits under the serial cutoff, so even the now-modeled
        // threads axis ties there; 512³ is where the modeled axes move.
        let gemm = Problem::Gemm { m: 128, n: 128, k: 128 };
        let big = Problem::Gemm { m: 512, n: 512, k: 512 };
        let conv = Problem::Conv { window: 3, stride: 1 };

        // The ISA never moves a GemmPoint's predicted cost: the model
        // cannot see that axis, so every ISA variant of a blocking ties
        // and guided search keeps them together.
        let base = GemmPoint::default();
        for isa in Isa::all() {
            let p = GemmPoint { isa, ..base };
            assert_eq!(p.rank_hint(&gemm), base.rank_hint(&gemm));
            assert_eq!(p.rank_hint(&big), base.rank_hint(&big));
            assert_eq!(p.rank_hint(&conv), base.rank_hint(&conv));
        }

        // The threads axis IS modeled — but only above the serial
        // cutoff, where the engine would actually fan out.  Below it
        // every thread count ties; above it more threads rank cheaper,
        // never at ideal speedup.
        let t1 = GemmPoint {
            params: BlockedParams { threads: 1, ..base.params },
            ..base
        };
        let t8 = GemmPoint {
            params: BlockedParams { threads: 8, ..base.params },
            ..base
        };
        assert_eq!(t1.rank_hint(&gemm), t8.rank_hint(&gemm), "under cutoff");
        let (c1, c8) =
            (t1.rank_hint(&big).unwrap(), t8.rank_hint(&big).unwrap());
        assert!(c8 < c1, "{c8} !< {c1}");
        assert!(c8 > c1 / 8.0, "never ideal speedup");

        // The pack axis IS modeled: on a many-band problem the packed-B
        // copy amortizes, so `ab` ranks cheaper than its `a` twin.
        let gab = GemmPoint { pack: Pack::Ab, ..base };
        assert!(gab.rank_hint(&big).unwrap() < base.rank_hint(&big).unwrap());

        // The dtype axis IS modeled: an i8 point is predicted cheaper
        // than its f32 twin (quarter traffic, denser lanes) for both
        // spaces, but never free.
        let gi8 = GemmPoint { dtype: Dtype::I8, ..base };
        assert!(gi8.rank_hint(&gemm).unwrap() < base.rank_hint(&gemm).unwrap());
        assert!(gi8.rank_hint(&gemm).unwrap() > 0.0);
        let cbase8 = ConvPoint { dtype: Dtype::I8, ..ConvPoint::default() };
        assert!(
            cbase8.rank_hint(&conv).unwrap()
                < ConvPoint::default().rank_hint(&conv).unwrap()
        );

        // ConvPoint: the ISA still ties; threads and pack are modeled
        // (conv problems carry no dims, so threads are priced with no
        // cutoff gate).
        let cbase = ConvPoint::default();
        for isa in Isa::all() {
            let ci = ConvPoint { isa, ..cbase };
            assert_eq!(ci.rank_hint(&conv), cbase.rank_hint(&conv));
        }
        let ct1 = ConvPoint {
            blocked: BlockedParams { threads: 1, ..cbase.blocked },
            ..cbase
        };
        let ct8 = ConvPoint {
            blocked: BlockedParams { threads: 8, ..cbase.blocked },
            ..cbase
        };
        assert!(
            ct8.rank_hint(&conv).unwrap() < ct1.rank_hint(&conv).unwrap()
        );
        let cab = ConvPoint { pack: Pack::Ab, ..cbase };
        assert!(
            cab.rank_hint(&conv).unwrap() < cbase.rank_hint(&conv).unwrap()
        );

        // Modeled axes do move it: a Winograd point is predicted
        // cheaper than default im2col on its 3×3/s1 domain, and the
        // wino_m axis is itself modeled (F(4×4) amortizes more).
        let wino2 = ConvPoint {
            config: ConvConfig::winograd(2),
            blocked: cbase.blocked,
            isa: cbase.isa,
            dtype: cbase.dtype,
            pack: cbase.pack,
        };
        let wino4 = ConvPoint {
            config: ConvConfig::winograd(4),
            ..wino2
        };
        assert!(
            wino2.rank_hint(&conv).unwrap() < cbase.rank_hint(&conv).unwrap()
        );
        assert!(
            wino4.rank_hint(&conv).unwrap() < wino2.rank_hint(&conv).unwrap()
        );

        // The modeled zoo spaces have no per-point model: unranked.
        assert!(GemmConfig::default().rank_hint(&gemm).is_none());
        assert!(ConvConfig::default().rank_hint(&conv).is_none());
    }

    #[test]
    fn modeled_spaces_roundtrip_their_historical_layout() {
        let g = GemmConfig::parse("8x4_8x16_noloc").unwrap();
        assert_eq!(g.to_json(), Value::Str("8x4_8x16_noloc".into()));
        assert_eq!(GemmConfig::from_json(&g.to_json()).unwrap(), g);
        assert_eq!(<GemmConfig as KernelSpace>::POINT_FIELD, "config");

        let c = ConvConfig::tiled(4, 4, 4, 2);
        assert_eq!(ConvConfig::from_json(&c.to_json()).unwrap(), c);
        assert_eq!(<ConvConfig as KernelSpace>::POINT_FIELD, "config");

        // Kind strings are pairwise distinct across the four spaces.
        let kinds = [
            GemmPoint::KIND,
            ConvPoint::KIND,
            <GemmConfig as KernelSpace>::KIND,
            <ConvConfig as KernelSpace>::KIND,
        ];
        for (i, k) in kinds.iter().enumerate() {
            assert!(!kinds[i + 1..].contains(k), "{k} duplicated");
        }
    }
}
