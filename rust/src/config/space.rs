//! Configuration-space enumeration for the tuner.
//!
//! The paper's tuning workflow sweeps the template-parameter space and
//! keeps what performs best per device.  These iterators define the
//! canonical search spaces.

use super::{ConvAlgorithm, ConvConfig, GemmConfig};

/// The monomorphized `(mr, nr)` register micro-tile shapes of the host
/// GEMM kernel — re-exported from the macro-generated registry in
/// `blas::blocked` so tuner grids, validation
/// (`BlockedParams::is_monomorphized`), and dispatch share one source
/// of truth (at least `{2, 4, 8, 16} × {4, 8, 16}`).
pub use crate::blas::MICRO_KERNEL_SHAPES;

/// The legal monomorphized micro-kernel shapes as a slice, for sweep
/// construction: `micro_kernel_shapes().iter()` enumerates every
/// `(mr, nr)` the host kernel dispatches to a fixed-trip-count kernel.
pub fn micro_kernel_shapes() -> &'static [(usize, usize)] {
    MICRO_KERNEL_SHAPES
}

/// The GEMM search space: register tiles x work-groups x memory schedule.
#[derive(Debug, Clone)]
pub struct GemmSpace {
    /// Candidate register-tile side lengths.
    pub reg_tiles: Vec<u32>,
    /// Candidate work-group side lengths.
    pub work_groups: Vec<u32>,
    /// Whether to include local-memory (`_loc`) variants.
    pub include_local: bool,
    /// Whether to include cache-only (`_noloc`) variants.
    pub include_noloc: bool,
    /// Whether to include double-buffered local-memory variants.
    pub include_double_buffer: bool,
}

impl Default for GemmSpace {
    fn default() -> Self {
        Self {
            reg_tiles: vec![1, 2, 4, 8],
            work_groups: vec![4, 8, 16],
            include_local: true,
            include_noloc: true,
            include_double_buffer: true,
        }
    }
}

impl GemmSpace {
    /// Enumerate every configuration in the space.
    pub fn enumerate(&self) -> Vec<GemmConfig> {
        let mut out = Vec::new();
        for &rt_m in &self.reg_tiles {
            for &rt_n in &self.reg_tiles {
                for &wg_r in &self.work_groups {
                    for &wg_c in &self.work_groups {
                        let mut variants = Vec::new();
                        if self.include_local {
                            variants.push((true, false));
                            if self.include_double_buffer {
                                variants.push((true, true));
                            }
                        }
                        if self.include_noloc {
                            variants.push((false, false));
                        }
                        for (use_local, double_buffer) in variants {
                            out.push(GemmConfig {
                                rt_m,
                                rt_n,
                                wg_r,
                                wg_c,
                                use_local,
                                double_buffer,
                                ..Default::default()
                            });
                        }
                    }
                }
            }
        }
        out
    }
}

/// Default GEMM search space (the paper's Table-2 region and around it).
pub fn gemm_space() -> Vec<GemmConfig> {
    GemmSpace::default().enumerate()
}

/// The convolution search space: tiles x vector widths x algorithms
/// (the sweep of paper Figs. 2 & 3).
#[derive(Debug, Clone)]
pub struct ConvSpace {
    /// Candidate output-tile heights.
    pub tiles_h: Vec<u32>,
    /// Candidate output-tile widths.
    pub tiles_w: Vec<u32>,
    /// Candidate input-channel vector widths.
    pub vecs_c: Vec<u32>,
    /// Candidate output-channel vector widths.
    pub vecs_k: Vec<u32>,
    /// Algorithms to sweep.
    pub algorithms: Vec<ConvAlgorithm>,
    /// Winograd output-tile sizes (used by the Winograd algorithm only).
    pub wino_ms: Vec<u32>,
}

impl Default for ConvSpace {
    fn default() -> Self {
        Self {
            tiles_h: vec![1, 2, 3, 4, 5],
            tiles_w: vec![1, 2, 3, 4, 5],
            vecs_c: vec![1, 2, 4],
            vecs_k: vec![1, 2, 4],
            algorithms: vec![
                ConvAlgorithm::Tiled,
                ConvAlgorithm::Im2col,
                ConvAlgorithm::Winograd,
            ],
            wino_ms: vec![2, 4],
        }
    }
}

impl ConvSpace {
    /// Enumerate configurations applicable to the given layer shape.
    pub fn enumerate(&self, window: u32, stride: u32) -> Vec<ConvConfig> {
        let mut out = Vec::new();
        for &alg in &self.algorithms {
            if !alg.supports(window, stride) {
                continue;
            }
            match alg {
                ConvAlgorithm::Winograd => {
                    for &m in &self.wino_ms {
                        for &vc in &self.vecs_c {
                            for &vk in &self.vecs_k {
                                out.push(ConvConfig {
                                    algorithm: alg,
                                    wino_m: m,
                                    vec_c: vc,
                                    vec_k: vk,
                                    ..Default::default()
                                });
                            }
                        }
                    }
                }
                ConvAlgorithm::Im2col => out.push(ConvConfig::im2col()),
                _ => {
                    for &th in &self.tiles_h {
                        for &tw in &self.tiles_w {
                            for &vc in &self.vecs_c {
                                for &vk in &self.vecs_k {
                                    out.push(ConvConfig {
                                        tile_h: th,
                                        tile_w: tw,
                                        vec_c: vc,
                                        vec_k: vk,
                                        algorithm: alg,
                                        ..Default::default()
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// Default convolution search space for a layer shape.
pub fn conv_space(window: u32, stride: u32) -> Vec<ConvConfig> {
    ConvSpace::default().enumerate(window, stride)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_space_contains_table2() {
        let space = gemm_space();
        for cfg in GemmConfig::table2() {
            assert!(
                space.contains(&cfg),
                "table2 config {} missing from default space",
                cfg.name()
            );
        }
    }

    #[test]
    fn gemm_space_size() {
        // 4 rt x 4 rt x 3 wg x 3 wg x 3 variants (loc, loc_db, noloc)
        assert_eq!(gemm_space().len(), 4 * 4 * 3 * 3 * 3);
    }

    #[test]
    fn conv_space_respects_winograd_domain() {
        let s1 = conv_space(3, 1);
        assert!(s1.iter().any(|c| c.algorithm == ConvAlgorithm::Winograd));
        let s2 = conv_space(3, 2);
        assert!(!s2.iter().any(|c| c.algorithm == ConvAlgorithm::Winograd));
        let s3 = conv_space(1, 1);
        assert!(!s3.iter().any(|c| c.algorithm == ConvAlgorithm::Winograd));
    }

    #[test]
    fn conv_space_all_valid(){
        for c in conv_space(3, 1) {
            c.validate().unwrap();
        }
    }

    #[test]
    fn micro_kernel_registry_is_the_shared_source_of_truth() {
        // Grids and validation must agree: every advertised shape is
        // registered, registered shapes validate, off-registry shapes do
        // not.
        use crate::blas::BlockedParams;
        assert_eq!(micro_kernel_shapes(), MICRO_KERNEL_SHAPES);
        for &(mr, nr) in micro_kernel_shapes() {
            let p = BlockedParams { mr, nr, ..Default::default() };
            assert!(p.is_monomorphized(), "({mr}, {nr})");
        }
        assert!(!BlockedParams { mr: 3, nr: 7, ..Default::default() }
            .is_monomorphized());
    }
}
