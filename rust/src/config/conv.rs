//! Convolution kernel configuration (paper §4.1).


use crate::error::{Error, Result};

/// Convolution algorithms provided by the library (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConvAlgorithm {
    /// Algorithm 1: one output element per thread.
    Naive,
    /// §4.1.1 tiled direct convolution.
    Tiled,
    /// Lower onto GEMM via im2col (the BLAS-backed path).
    Im2col,
    /// §4.1.2 Winograd/Cook-Toom fast convolution.
    Winograd,
}

impl ConvAlgorithm {
    /// All algorithms, in the order reports list them.
    pub fn all() -> [ConvAlgorithm; 4] {
        [
            ConvAlgorithm::Naive,
            ConvAlgorithm::Tiled,
            ConvAlgorithm::Im2col,
            ConvAlgorithm::Winograd,
        ]
    }

    /// Whether this algorithm can compute the given layer shape.
    /// Winograd applies to 3x3 stride-1 convolutions only.
    pub fn supports(&self, window: u32, stride: u32) -> bool {
        match self {
            ConvAlgorithm::Winograd => window == 3 && stride == 1,
            _ => true,
        }
    }

    /// Stable lowercase name (manifests, selection DB, reports).
    pub fn as_str(&self) -> &'static str {
        match self {
            ConvAlgorithm::Naive => "naive",
            ConvAlgorithm::Tiled => "tiled",
            ConvAlgorithm::Im2col => "im2col",
            ConvAlgorithm::Winograd => "winograd",
        }
    }
}

impl std::fmt::Display for ConvAlgorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for ConvAlgorithm {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "naive" => Ok(ConvAlgorithm::Naive),
            "tiled" => Ok(ConvAlgorithm::Tiled),
            "im2col" => Ok(ConvAlgorithm::Im2col),
            "winograd" => Ok(ConvAlgorithm::Winograd),
            other => Err(Error::Config(format!("unknown algorithm {other:?}"))),
        }
    }
}

/// Parameters of the tiled convolution kernel family (paper §4.1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConvConfig {
    /// Output-tile rows computed per thread.
    pub tile_h: u32,
    /// Output-tile columns computed per thread.
    pub tile_w: u32,
    /// Input-channel vector width (vector loads).
    pub vec_c: u32,
    /// Output-channel vector width (vector stores / accumulators).
    pub vec_k: u32,
    /// Output channels per grid cell (0 = all).
    pub block_k: u32,
    /// Which algorithm this configuration drives.
    pub algorithm: ConvAlgorithm,
    /// Winograd output-tile size m for F(m x m, 3 x 3).
    pub wino_m: u32,
}

impl Default for ConvConfig {
    fn default() -> Self {
        Self {
            tile_h: 1,
            tile_w: 1,
            vec_c: 1,
            vec_k: 1,
            block_k: 0,
            algorithm: ConvAlgorithm::Tiled,
            wino_m: 2,
        }
    }
}

impl ConvConfig {
    /// A tiled configuration with the given tile and vector widths.
    pub fn tiled(tile_h: u32, tile_w: u32, vec_c: u32, vec_k: u32) -> Self {
        Self {
            tile_h,
            tile_w,
            vec_c,
            vec_k,
            algorithm: ConvAlgorithm::Tiled,
            ..Default::default()
        }
    }

    /// The naive (Algorithm 1) configuration: 1x1 tile, scalar loads.
    pub fn naive() -> Self {
        Self {
            algorithm: ConvAlgorithm::Naive,
            ..Default::default()
        }
    }

    /// A Winograd configuration with output tile `m`.
    pub fn winograd(m: u32) -> Self {
        Self {
            algorithm: ConvAlgorithm::Winograd,
            wino_m: m,
            ..Default::default()
        }
    }

    /// An im2col/GEMM-backed configuration.
    pub fn im2col() -> Self {
        Self {
            algorithm: ConvAlgorithm::Im2col,
            ..Default::default()
        }
    }

    /// Output elements per thread.
    pub fn outputs_per_thread(&self) -> u32 {
        self.tile_h * self.tile_w * self.vec_k
    }

    /// Configuration name matching `python/compile/configs.py`.
    pub fn name(&self) -> String {
        match self.algorithm {
            ConvAlgorithm::Winograd => {
                format!("wino{}_v{}x{}", self.wino_m, self.vec_c, self.vec_k)
            }
            alg => format!(
                "{}_{}x{}_v{}x{}",
                alg, self.tile_h, self.tile_w, self.vec_c, self.vec_k
            ),
        }
    }

    /// Validate basic structural constraints.
    pub fn validate(&self) -> Result<()> {
        if self.tile_h == 0 || self.tile_w == 0 {
            return Err(Error::Config("zero conv tile".into()));
        }
        if self.vec_c == 0 || self.vec_k == 0 {
            return Err(Error::Config("zero vector width".into()));
        }
        if self.algorithm == ConvAlgorithm::Winograd
            && !matches!(self.wino_m, 2 | 4)
        {
            return Err(Error::Config(format!(
                "unsupported winograd m={}",
                self.wino_m
            )));
        }
        Ok(())
    }
}

impl std::fmt::Display for ConvConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_support_matrix() {
        assert!(ConvAlgorithm::Winograd.supports(3, 1));
        assert!(!ConvAlgorithm::Winograd.supports(3, 2));
        assert!(!ConvAlgorithm::Winograd.supports(1, 1));
        assert!(!ConvAlgorithm::Winograd.supports(7, 2));
        for alg in [ConvAlgorithm::Naive, ConvAlgorithm::Tiled, ConvAlgorithm::Im2col] {
            assert!(alg.supports(7, 2));
            assert!(alg.supports(1, 1));
        }
    }

    #[test]
    fn names_match_python_schema() {
        assert_eq!(ConvConfig::tiled(4, 5, 4, 2).name(), "tiled_4x5_v4x2");
        assert_eq!(ConvConfig::winograd(2).name(), "wino2_v1x1");
        assert_eq!(ConvConfig::naive().name(), "naive_1x1_v1x1");
        assert_eq!(ConvConfig::im2col().name(), "im2col_1x1_v1x1");
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(ConvConfig { tile_h: 0, ..Default::default() }.validate().is_err());
        assert!(ConvConfig { vec_c: 0, ..Default::default() }.validate().is_err());
        assert!(ConvConfig { wino_m: 3, algorithm: ConvAlgorithm::Winograd, ..Default::default() }
            .validate()
            .is_err());
        assert!(ConvConfig::tiled(4, 5, 4, 2).validate().is_ok());
    }

    #[test]
    fn algorithm_roundtrip() {
        for alg in ConvAlgorithm::all() {
            let s = alg.to_string();
            assert_eq!(s.parse::<ConvAlgorithm>().unwrap(), alg);
        }
        assert!("bogus".parse::<ConvAlgorithm>().is_err());
    }
}
