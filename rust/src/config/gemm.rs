//! GEMM kernel configuration (paper §3.1, Table 2).


use crate::error::{Error, Result};

/// Parameters of the blocked GEMM kernel family.
///
/// A configuration string `hxw_rxc[_loc|_noloc][_db]` follows the paper's
/// Table-2 naming: `h x w` is the per-thread register tile, `r x c` the
/// work-group thread grid.  The macro-tile of C computed per work-group is
/// therefore `(h*r) x (w*c)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmConfig {
    /// Register-tile rows per thread (`h`).
    pub rt_m: u32,
    /// Register-tile columns per thread (`w`).
    pub rt_n: u32,
    /// Work-group thread rows (`r`).
    pub wg_r: u32,
    /// Work-group thread columns (`c`).
    pub wg_c: u32,
    /// k'-panel depth staged per iteration, in elements.
    pub block_k: u32,
    /// Stage A/B panels through local memory (`_loc`).
    pub use_local: bool,
    /// Double-buffer the local staging tiles to overlap load and compute.
    pub double_buffer: bool,
}

impl Default for GemmConfig {
    fn default() -> Self {
        Self {
            rt_m: 4,
            rt_n: 4,
            wg_r: 8,
            wg_c: 8,
            block_k: 32,
            use_local: true,
            double_buffer: false,
        }
    }
}

impl GemmConfig {
    /// Rows of the C macro-tile per work-group.
    pub fn block_m(&self) -> u32 {
        self.rt_m * self.wg_r
    }

    /// Columns of the C macro-tile per work-group.
    pub fn block_n(&self) -> u32 {
        self.rt_n * self.wg_c
    }

    /// Accumulator registers per thread (Table 2 "Registers").
    pub fn registers(&self) -> u32 {
        self.rt_m * self.rt_n
    }

    /// Threads per work-group (Table 2 "Work group").
    pub fn work_group(&self) -> u32 {
        self.wg_r * self.wg_c
    }

    /// Local-memory footprint in **elements** for staging granularity
    /// `x` elements (paper §5.2: `h*r*X + X*w*c`, doubled when double
    /// buffering).  Zero for `_noloc` configurations.
    pub fn local_mem_elems(&self, x: u32) -> u32 {
        if !self.use_local {
            return 0;
        }
        let elems = self.rt_m * self.wg_r * x + x * self.rt_n * self.wg_c;
        if self.double_buffer {
            2 * elems
        } else {
            elems
        }
    }

    /// Local-memory footprint in bytes for f32 data.
    pub fn local_mem_bytes(&self, x: u32) -> u32 {
        4 * self.local_mem_elems(x)
    }

    /// Data-reuse ratio of the register tile (paper Eq. 3):
    /// `2*m'*n' / (m' + n')` flops per element loaded.
    pub fn reuse_ratio(&self) -> f64 {
        let m = self.rt_m as f64;
        let n = self.rt_n as f64;
        2.0 * m * n / (m + n)
    }

    /// Paper-style configuration name, e.g. `8x4_8x16_loc`.
    pub fn name(&self) -> String {
        let tag = if self.use_local { "loc" } else { "noloc" };
        let db = if self.double_buffer { "_db" } else { "" };
        format!(
            "{}x{}_{}x{}_{}{}",
            self.rt_m, self.rt_n, self.wg_r, self.wg_c, tag, db
        )
    }

    /// Parse a paper-style configuration string.
    ///
    /// (`no_run`: doctest binaries do not inherit the xla_extension
    /// rpath in this offline environment; the same assertions run as a
    /// unit test below.)
    ///
    /// ```no_run
    /// use portable_kernels::config::GemmConfig;
    /// let c = GemmConfig::parse("8x4_8x16_loc").unwrap();
    /// assert_eq!((c.rt_m, c.rt_n, c.wg_r, c.wg_c), (8, 4, 8, 16));
    /// assert!(c.use_local);
    /// assert_eq!(c.name(), "8x4_8x16_loc");
    /// ```
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split('_').collect();
        if parts.len() < 2 {
            return Err(Error::Config(format!("bad gemm config {s:?}")));
        }
        let pair = |p: &str| -> Result<(u32, u32)> {
            let (a, b) = p
                .split_once('x')
                .ok_or_else(|| Error::Config(format!("bad tile {p:?} in {s:?}")))?;
            let a: u32 = a
                .parse()
                .map_err(|_| Error::Config(format!("bad number in {s:?}")))?;
            let b: u32 = b
                .parse()
                .map_err(|_| Error::Config(format!("bad number in {s:?}")))?;
            if a == 0 || b == 0 {
                return Err(Error::Config(format!("zero tile dim in {s:?}")));
            }
            Ok((a, b))
        };
        let (rt_m, rt_n) = pair(parts[0])?;
        let (wg_r, wg_c) = pair(parts[1])?;
        let mut cfg = GemmConfig {
            rt_m,
            rt_n,
            wg_r,
            wg_c,
            ..Default::default()
        };
        for p in &parts[2..] {
            match *p {
                "loc" => cfg.use_local = true,
                "noloc" => cfg.use_local = false,
                "db" => cfg.double_buffer = true,
                other => {
                    return Err(Error::Config(format!(
                        "bad suffix {other:?} in {s:?}"
                    )))
                }
            }
        }
        Ok(cfg)
    }

    /// The seven configurations evaluated in the paper (Table 2).
    pub fn table2() -> Vec<GemmConfig> {
        [
            "4x4_8x8_loc",
            "4x4_16x16_loc",
            "8x4_8x16_loc",
            "8x2_4x16_loc",
            "8x4_8x16_noloc",
            "8x4_4x8_noloc",
            "4x4_8x8_noloc",
        ]
        .iter()
        .map(|s| GemmConfig::parse(s).expect("table2 configs are valid"))
        .collect()
    }
}

impl std::fmt::Display for GemmConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_table2() {
        for cfg in GemmConfig::table2() {
            assert_eq!(GemmConfig::parse(&cfg.name()).unwrap(), cfg);
        }
    }

    #[test]
    fn table2_registers_and_workgroups() {
        // Paper Table 2 columns.
        let by_name: std::collections::HashMap<String, GemmConfig> =
            GemmConfig::table2()
                .into_iter()
                .map(|c| (c.name(), c))
                .collect();
        assert_eq!(by_name["4x4_8x8_loc"].registers(), 16);
        assert_eq!(by_name["4x4_8x8_loc"].work_group(), 64);
        assert_eq!(by_name["4x4_16x16_loc"].work_group(), 256);
        assert_eq!(by_name["8x4_8x16_loc"].registers(), 32);
        assert_eq!(by_name["8x4_8x16_loc"].work_group(), 128);
        assert_eq!(by_name["8x4_4x8_noloc"].work_group(), 32);
    }

    #[test]
    fn table2_local_mem_column() {
        // X = 32 elements (back-solved from the paper's Table 2; see
        // python/compile/configs.py).
        let kib = |s: &str| GemmConfig::parse(s).unwrap().local_mem_bytes(32) / 1024;
        assert_eq!(kib("4x4_8x8_loc"), 8);
        assert_eq!(kib("4x4_16x16_loc"), 16);
        assert_eq!(kib("8x4_8x16_loc"), 16);
        assert_eq!(kib("8x2_4x16_loc"), 8);
        assert_eq!(kib("8x4_8x16_noloc"), 0);
    }

    #[test]
    fn double_buffer_doubles() {
        let a = GemmConfig::parse("8x4_8x16_loc").unwrap();
        let b = GemmConfig::parse("8x4_8x16_loc_db").unwrap();
        assert_eq!(b.local_mem_elems(32), 2 * a.local_mem_elems(32));
    }

    #[test]
    fn reuse_ratio_square_beats_nonsquare_at_equal_registers() {
        // Paper Fig. 4b: 4x4 (square) vs 8x2 (non-square), both 16 regs.
        let sq = GemmConfig::parse("4x4_8x8_loc").unwrap();
        let ns = GemmConfig::parse("8x2_4x16_loc").unwrap();
        assert_eq!(sq.registers(), ns.registers());
        assert!(sq.reuse_ratio() > ns.reuse_ratio());
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["", "4x4", "4x4_8x8_bogus", "0x4_8x8_loc", "4_8x8_loc"] {
            assert!(GemmConfig::parse(bad).is_err(), "{bad}");
        }
    }
}
