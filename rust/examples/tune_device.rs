//! Tune the parametrized kernels for two very different devices and show
//! that the winning parameters differ — the paper's core portability
//! workflow ("tuning for new devices amounts to choosing the combinations
//! of kernel parameters that perform best on the hardware").
//!
//! ```sh
//! cargo run --release --example tune_device
//! ```

use portable_kernels::config::GemmConfig;
use portable_kernels::device::device_by_name;
use portable_kernels::perfmodel::{gemm_estimate, GemmProblem};
use portable_kernels::tuner::{
    tune_conv, tune_gemm, ExhaustiveSearch, HillClimb, SelectionDb,
    SelectionKey,
};
use portable_kernels::util::tmp::TempDir;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let devices = ["mali-g71", "r9-nano", "uhd630", "i7-6700k-cpu"];
    let problems = [
        GemmProblem::new(128, 128, 128),
        GemmProblem::new(512, 512, 512),
        GemmProblem::new(1024, 1024, 1024),
    ];

    println!("== GEMM: winning configuration per device per size ==");
    let mut db = SelectionDb::new();
    for dev_id in devices {
        let dev = device_by_name(dev_id)?;
        for p in problems {
            let r = tune_gemm(&dev, p, &ExhaustiveSearch)
                .expect("space is non-empty");
            println!(
                "{:>13}  {:>4}^3  -> {:<16} {:>8.1} GF  ({} evaluated, {} infeasible)",
                dev_id,
                p.m,
                r.config.name(),
                r.gflops,
                r.evaluated,
                r.infeasible
            );
            db.put_gemm(
                SelectionKey::gemm(dev_id, p.m, p.n, p.k),
                r.config,
                r.gflops,
            );
        }
    }

    // The portability claim, demonstrated: the tuned config for Mali
    // (cache-based, no local memory) differs from the R9 Nano's.
    let mali = db
        .get_gemm(&SelectionKey::gemm("mali-g71", 1024, 1024, 1024))
        .unwrap()
        .0;
    let amd = db
        .get_gemm(&SelectionKey::gemm("r9-nano", 1024, 1024, 1024))
        .unwrap()
        .0;
    println!(
        "\nmali winner {} vs r9-nano winner {} -> device-specific tuning",
        mali.name(),
        amd.name()
    );
    assert_ne!(mali, amd);

    // How much does tuning buy over a one-size-fits-all default?
    println!("\n== tuned vs default (4x4_8x8_loc) ==");
    for dev_id in devices {
        let dev = device_by_name(dev_id)?;
        let p = GemmProblem::new(1024, 1024, 1024);
        let tuned = tune_gemm(&dev, p, &ExhaustiveSearch).unwrap();
        let default = gemm_estimate(&dev, p, &GemmConfig::default())?;
        println!(
            "{:>13}: tuned {:>8.1} GF vs default {:>8.1} GF  ({:.2}x)",
            dev_id,
            tuned.gflops,
            default.gflops,
            tuned.gflops / default.gflops
        );
    }

    // Conv layers: hill-climbing finds (nearly) the exhaustive winner in
    // a fraction of the evaluations — the paper's planned "ML tuner".
    println!("\n== conv conv3_1-like layer: exhaustive vs hill-climb ==");
    let layer = portable_kernels::nn::ConvLayer::same(
        "demo", 3, 1, 56, 56, 128, 256,
    );
    for dev_id in devices {
        let dev = device_by_name(dev_id)?;
        let ex = tune_conv(&dev, &layer, 1, &ExhaustiveSearch).unwrap();
        let hc =
            tune_conv(&dev, &layer, 1, &HillClimb { restarts: 6, seed: 9 })
                .unwrap();
        println!(
            "{:>13}: exhaustive {} @ {:.1} GF ({} evals) | hillclimb {} @ {:.1} GF ({} evals)",
            dev_id,
            ex.config.name(),
            ex.gflops,
            ex.evaluated,
            hc.config.name(),
            hc.gflops,
            hc.evaluated
        );
    }

    // Persist + reload the selection DB (what a deployment ships).
    let tmp = TempDir::new("tune-demo")?;
    let path = tmp.path().join("selections.json");
    db.save(&path)?;
    let loaded = SelectionDb::load(&path)?;
    println!("\nselection DB round-trip: {} entries OK", loaded.len());
    Ok(())
}
