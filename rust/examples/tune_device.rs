//! Tune the parametrized kernels — modeled for the paper's device zoo,
//! *measured* for the host we are actually running on.
//!
//! Two halves:
//!
//! 1. **Modeled** (full mode only): tune the device zoo through the
//!    analytic model and show the winning parameters differ per device —
//!    the paper's core portability workflow.
//! 2. **Measured**: the real per-host sweep, one generic loop per
//!    kernel space (`tuner::tune_space_sweep`), parameterized by a
//!    `--search` strategy.  Enumerate the GEMM space grid
//!    (`BlockedParams` × `threads` × runtime-detected micro-kernel
//!    **ISA** — scalar/SSE2/AVX2/FMA/AVX-512 on x86-64 — × **dtype**,
//!    f32 vs quantized i8 — × **pack**, A-only vs A+B panel packing)
//!    and the conv space grid
//!    (`ConvAlgorithm × ConvConfig × threads × ISA × dtype × pack` —
//!    tiled vs
//!    im2col vs winograd with its `wino_m ∈ {2, 4}` tile size, the
//!    paper's §4.1 algorithm axis, plus the micro-kernel ISA the
//!    lowered transform-domain/im2col GEMMs dispatch; i8 rides the
//!    im2col lowering only), let the strategy pick which applicable
//!    points to execute through `NativeEngine` via
//!    `Backend::run_timed`, persist the winners into a `SelectionDb`,
//!    and prove the engine consults it — including the chosen
//!    algorithm, ISA, dtype and pack — at plan time.  A final 512^3
//!    head-to-head times tuned int8 against tuned f32 in
//!    elements/second (>= 2x asserted on AVX2 hosts), and a pack
//!    head-to-head times the best A+B point against the best A-only
//!    point at the same size (CI asserts ab does not lose).
//!
//! ```sh
//! cargo run --release --example tune_device              # full, guided
//! cargo run --release --example tune_device -- --quick   # CI smoke
//! cargo run --release --example tune_device -- --quick --out reports
//! cargo run --release --example tune_device -- --quick \
//!     --search exhaustive       # measure the whole grid
//! cargo run --release --example tune_device -- --quick \
//!     --search guided --budget 4  # tight per-class probe budget
//! cargo run --release --example tune_device -- --quick --out reports \
//!     --merge old_reports/tuning_host.json   # fold a legacy DB in
//! ```
//!
//! `--search` picks the [`SearchStrategy`]: `guided` (default — the
//! `perfmodel` cost hints rank the grid and only the top candidates
//! plus the pinned default/incumbent are measured, capped at `--budget`
//! points per shape class), `exhaustive` (measure every applicable
//! point), or `hill` (seeded hill-climb).  Outputs (measured half):
//! `<out>/tuning_host.json` (the persisted selection DB, unified
//! `gemm_point`/`conv_point` schema, each entry annotated with `search`
//! and `points_measured`) and `<out>/BENCH_ci.json` (tuned-vs-default
//! GFLOP/s per problem with `points_measured` per problem, `algorithm`
//! + `wino_m` + `isa` columns on conv rows and `isa` columns on GEMM
//! rows, and the top level `search` column CI keys its
//! guided-vs-exhaustive assertions on).  `--merge OLD.json` folds a previously written (possibly legacy
//! `blocked`/`conv_native`) DB into the unified schema, keeping the
//! faster entry per key.  Exits non-zero if the sweep produced no
//! selections, a tuned config measured below the default, or — under
//! `--search exhaustive`, where full coverage is the contract — the
//! algorithm or ISA axis collapsed.

use std::path::{Path, PathBuf};

use portable_kernels::blas::{
    gemm_blocked_ex, gemm_blocked_isa, gemm_i8_dequant, gemm_workspace,
    quantize_slice, Dtype, Isa, Pack, QuantParams,
};
use portable_kernels::config::{
    ConvAlgorithm, ConvPoint, GemmConfig, GemmPoint,
};
use portable_kernels::device::device_by_name;
use portable_kernels::perfmodel::{gemm_estimate, GemmProblem};
use portable_kernels::runtime::{
    ArtifactStore, Backend, NativeEngine, HOST_DEVICE,
};
use portable_kernels::config::KernelSpace;
use portable_kernels::tuner::{
    conv_native_grid, gemm_point_grid, selection_key_for, tune_conv,
    tune_gemm, tune_space_sweep, ExhaustiveSearch, GuidedSearch, HillClimb,
    SearchStrategy, SelectionDb, SelectionKey, SpaceMeasurement, SpaceSweep,
};
use portable_kernels::util::bench::{bench, black_box};
use portable_kernels::util::json::Value;
use portable_kernels::util::rng::XorShift;
use portable_kernels::util::scratch::Scratch;
use portable_kernels::util::tmp::TempDir;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut quick = false;
    let mut out_dir = PathBuf::from("reports");
    let mut merge_path: Option<PathBuf> = None;
    let mut search = String::from("guided");
    let mut budget = 8usize;
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--quick" => quick = true,
            "--out" => {
                out_dir = PathBuf::from(
                    it.next().ok_or("--out needs a directory argument")?,
                );
            }
            "--merge" => {
                merge_path = Some(PathBuf::from(
                    it.next().ok_or("--merge needs a DB path argument")?,
                ));
            }
            "--search" => {
                search = it
                    .next()
                    .ok_or("--search needs exhaustive|guided|hill")?;
            }
            "--budget" => {
                budget = it
                    .next()
                    .ok_or("--budget needs a point count")?
                    .parse()
                    .map_err(|e| format!("bad --budget: {e}"))?;
            }
            other => {
                return Err(format!(
                    "unknown argument {other:?}; \
                     usage: tune_device [--quick] [--out DIR] \
                     [--search exhaustive|guided|hill] [--budget N] \
                     [--merge OLD.json]"
                )
                .into())
            }
        }
    }

    if !quick {
        modeled_zoo()?;
    }
    measured_host_sweep(quick, &out_dir, merge_path.as_deref(), &search, budget)
}

/// The modeled half: the paper's device zoo through the analytic model.
fn modeled_zoo() -> Result<(), Box<dyn std::error::Error>> {
    let devices = ["mali-g71", "r9-nano", "uhd630", "i7-6700k-cpu"];
    let problems = [
        GemmProblem::new(128, 128, 128),
        GemmProblem::new(512, 512, 512),
        GemmProblem::new(1024, 1024, 1024),
    ];

    println!("== GEMM: winning configuration per device per size ==");
    let mut db = SelectionDb::new();
    for dev_id in devices {
        let dev = device_by_name(dev_id)?;
        for p in problems {
            let r = tune_gemm(&dev, p, &ExhaustiveSearch)
                .expect("space is non-empty");
            println!(
                "{:>13}  {:>4}^3  -> {:<16} {:>8.1} GF  ({} evaluated, {} infeasible)",
                dev_id,
                p.m,
                r.config.name(),
                r.gflops,
                r.evaluated,
                r.infeasible
            );
            db.put(
                SelectionKey::gemm(dev_id, p.m, p.n, p.k),
                r.config,
                r.gflops,
            );
        }
    }

    // The portability claim, demonstrated: the tuned config for Mali
    // (cache-based, no local memory) differs from the R9 Nano's.
    let mali = db
        .get::<GemmConfig>(&SelectionKey::gemm("mali-g71", 1024, 1024, 1024))
        .unwrap()
        .0;
    let amd = db
        .get::<GemmConfig>(&SelectionKey::gemm("r9-nano", 1024, 1024, 1024))
        .unwrap()
        .0;
    println!(
        "\nmali winner {} vs r9-nano winner {} -> device-specific tuning",
        mali.name(),
        amd.name()
    );
    assert_ne!(mali, amd);

    // How much does tuning buy over a one-size-fits-all default?
    println!("\n== tuned vs default (4x4_8x8_loc) ==");
    for dev_id in devices {
        let dev = device_by_name(dev_id)?;
        let p = GemmProblem::new(1024, 1024, 1024);
        let tuned = tune_gemm(&dev, p, &ExhaustiveSearch).unwrap();
        let default = gemm_estimate(&dev, p, &GemmConfig::default())?;
        println!(
            "{:>13}: tuned {:>8.1} GF vs default {:>8.1} GF  ({:.2}x)",
            dev_id,
            tuned.gflops,
            default.gflops,
            tuned.gflops / default.gflops
        );
    }

    // Conv layers: hill-climbing finds (nearly) the exhaustive winner in
    // a fraction of the evaluations — the paper's planned "ML tuner".
    println!("\n== conv conv3_1-like layer: exhaustive vs hill-climb ==");
    let layer = portable_kernels::nn::ConvLayer::same(
        "demo", 3, 1, 56, 56, 128, 256,
    );
    for dev_id in devices {
        let dev = device_by_name(dev_id)?;
        let ex = tune_conv(&dev, &layer, 1, &ExhaustiveSearch).unwrap();
        let hc =
            tune_conv(&dev, &layer, 1, &HillClimb { restarts: 6, seed: 9 })
                .unwrap();
        println!(
            "{:>13}: exhaustive {} @ {:.1} GF ({} evals) | hillclimb {} @ {:.1} GF ({} evals)",
            dev_id,
            ex.config.name(),
            ex.gflops,
            ex.evaluated,
            hc.config.name(),
            hc.gflops,
            hc.evaluated
        );
    }
    println!();
    Ok(())
}

/// One synthetic gemm manifest entry.  The `quant` block matters: the
/// sweep's grid crosses `dtype ∈ {f32, i8}`, and without quantization
/// metadata the planner degrades i8 points to f32 — the sweep would
/// silently time the f32 kernel under an i8 label.  `synth_inputs`
/// draws from [-0.5, 0.5), so scale 1/256 with zero-point 0 spans the
/// data range.
fn gemm_entry(name: &str, m: usize, n: usize, k: usize) -> String {
    let flops = 2 * m as u64 * n as u64 * k as u64;
    format!(
        r#"{{"name": "{name}", "kind": "gemm", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops},
            "m": {m}, "n": {n}, "k": {k}, "groups": ["gemm"],
            "quant": {{"a": {{"scale": 0.00390625, "zero_point": 0}},
                       "b": {{"scale": 0.00390625, "zero_point": 0}}}},
            "inputs": [{{"shape": [{m}, {k}], "dtype": "float32"}},
                       {{"shape": [{k}, {n}], "dtype": "float32"}}]}}"#
    )
}

/// One synthetic SAME-padded conv manifest entry.
fn conv_entry(
    name: &str,
    batch: usize,
    h: usize,
    c: usize,
    k: usize,
    window: usize,
) -> String {
    let flops = 2 * (batch * h * h * k * window * window * c) as u64;
    format!(
        r#"{{"name": "{name}", "kind": "conv", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops}, "batch": {batch},
            "algorithm": "im2col", "groups": ["conv"],
            "quant": {{"a": {{"scale": 0.00390625, "zero_point": 0}},
                       "b": {{"scale": 0.00390625, "zero_point": 0}}}},
            "layer": {{"name": "{name}", "window": {window}, "stride": 1,
                       "in_h": {h}, "in_w": {h}, "in_c": {c}, "out_c": {k},
                       "out_h": {h}, "out_w": {h}, "padding": "SAME",
                       "flops": {flops}}},
            "inputs": [{{"shape": [{batch}, {h}, {h}, {c}], "dtype": "float32"}},
                       {{"shape": [{window}, {window}, {c}, {k}], "dtype": "float32"}}]}}"#
    )
}

/// Build the store the sweep measures: real AOT artifacts when present
/// (full mode), otherwise a synthetic manifest with shapes big enough
/// that blocking and threads both matter (the native backend never opens
/// HLO files, so the manifest alone specifies execution).
fn sweep_store(
    quick: bool,
) -> Result<(Option<TempDir>, ArtifactStore), Box<dyn std::error::Error>> {
    let real = Path::new("artifacts");
    if !quick && real.join("manifest.json").exists() {
        return Ok((None, ArtifactStore::open(real)?));
    }
    let entries: Vec<String> = if quick {
        vec![
            gemm_entry("host_gemm_96", 96, 96, 96),
            conv_entry("host_conv_16", 2, 16, 8, 16, 3),
        ]
    } else {
        vec![
            gemm_entry("host_gemm_128", 128, 128, 128),
            gemm_entry("host_gemm_256", 256, 256, 256),
            conv_entry("host_conv_32", 2, 32, 16, 32, 3),
        ]
    };
    let dir = TempDir::new("host-sweep")?;
    std::fs::write(
        dir.path().join("manifest.json"),
        format!(
            r#"{{"version": 1, "artifacts": [{}]}}"#,
            entries.join(",\n")
        ),
    )?;
    let store = ArtifactStore::open(dir.path())?;
    Ok((Some(dir), store))
}

/// Per-dtype argmax columns for one problem: within each precision the
/// tuned winner is the max over a superset of that precision's scalar
/// rows, so tuned >= scalar *per dtype* is an argmax invariant, not a
/// timing assertion — violated only if the sweep mislabeled rows.  CI
/// additionally keys on the i8 pair (tuned-i8 >= scalar-i8).  Integer
/// rows report GOP/s, f32 rows GFLOP/s — same useful-op count, honest
/// unit.
fn per_dtype_columns<P: KernelSpace>(
    rows: &[SpaceMeasurement<P>],
    op: &str,
    dtype_of: &dyn Fn(&P) -> Dtype,
    isa_of: &dyn Fn(&P) -> Isa,
) -> Result<Value, Box<dyn std::error::Error>> {
    let mut per = Value::object();
    for d in Dtype::all() {
        let best = |scalar_only: bool| -> f64 {
            rows.iter()
                .filter(|r| {
                    r.problem == op
                        && dtype_of(&r.point) == d
                        && (!scalar_only || isa_of(&r.point) == Isa::Scalar)
                })
                .map(|r| r.gflops)
                .fold(0.0f64, f64::max)
        };
        let tuned = best(false);
        if tuned <= 0.0 {
            // This precision was never measured for this problem (a
            // budgeted strategy pruned it, or i8 is off-domain).
            continue;
        }
        let scalar = best(true);
        if tuned < scalar {
            return Err(format!(
                "{op}: tuned {d} {tuned:.2} below the scalar {d} winner \
                 {scalar:.2} — per-dtype argmax violated"
            )
            .into());
        }
        let mut o = Value::object();
        if d == Dtype::I8 {
            o.set("tuned_gops", tuned).set("scalar_gops", scalar);
        } else {
            o.set("tuned_gflops", tuned).set("scalar_gflops", scalar);
        }
        per.set(d.as_str(), o);
    }
    Ok(per)
}

/// The measured half: one generic sweep per kernel space (GEMM:
/// `BlockedParams × threads × ISA`; conv: `ConvAlgorithm × ConvConfig ×
/// threads × ISA`, the config axis carrying the Winograd `wino_m` tile
/// size) under the chosen strategy, persist, optionally fold a legacy
/// DB in, and prove the engine consults the DB — algorithm and ISA
/// included — at plan time.
fn measured_host_sweep(
    quick: bool,
    out_dir: &Path,
    merge_path: Option<&Path>,
    search: &str,
    budget: usize,
) -> Result<(), Box<dyn std::error::Error>> {
    let mode = if quick { "quick" } else { "full" };
    println!("== measured host sweep ({mode}, search={search}) ==");
    std::fs::create_dir_all(out_dir)?;

    let strategy: Box<dyn SearchStrategy> = match search {
        "exhaustive" => Box::new(ExhaustiveSearch),
        "guided" => Box::new(GuidedSearch { budget }),
        "hill" => Box::new(HillClimb { restarts: budget.max(1), seed: 42 }),
        other => {
            return Err(format!(
                "unknown --search {other:?}; use exhaustive|guided|hill"
            )
            .into())
        }
    };
    // Full coverage of every axis is only the contract when every point
    // gets measured; budgeted strategies prune by design.
    let exhaustive = search == "exhaustive";

    let (_tmp, store) = sweep_store(quick)?;
    let mut engine = NativeEngine::new(store)?;
    let threads: &[usize] =
        if quick { &[1, 2] } else { &[1, 2, 4, 0] };
    let isas = Isa::detect();
    let grid = gemm_point_grid(quick, threads, &isas);
    let conv_grid = conv_native_grid(quick, threads, &isas);
    let iters = if quick { 3 } else { 5 };
    println!(
        "detected ISAs: {:?}; gemm grid: {} blocking x threads x isa \
         points; conv grid: {} algorithm x config x threads x isa \
         points; {} iters each; search {} (budget {})",
        isas.iter().map(|i| i.as_str()).collect::<Vec<_>>(),
        grid.len(),
        conv_grid.len(),
        iters,
        search,
        budget
    );

    let mut db = SelectionDb::new();
    let gemm_sweep: SpaceSweep<GemmPoint> = tune_space_sweep(
        &mut engine,
        "gemm",
        &grid,
        iters,
        HOST_DEVICE,
        strategy.as_ref(),
        &mut |e, p| e.set_gemm_point(*p),
        &mut db,
    )?;
    for (op, (point, gflops)) in &gemm_sweep.winners {
        // Integer winners report GOP/s — same useful-op count, honest
        // unit (satellite of the dtype axis; see util::bench::gops).
        let unit =
            if point.dtype == Dtype::I8 { "GOP/s" } else { "GF/s" };
        println!(
            "  {op:<28} -> [{}] {:<30} {gflops:>8.2} {unit} \
             ({} points measured)",
            point.isa,
            point.name(),
            gemm_sweep.points_measured_for(op)
        );
    }
    let conv_sweep: SpaceSweep<ConvPoint> = tune_space_sweep(
        &mut engine,
        "conv",
        &conv_grid,
        iters,
        HOST_DEVICE,
        strategy.as_ref(),
        &mut |e, c| e.set_conv_point(*c),
        &mut db,
    )?;
    for (op, (cand, gflops)) in &conv_sweep.winners {
        let unit =
            if cand.dtype == Dtype::I8 { "GOP/s" } else { "GF/s" };
        println!(
            "  {op:<28} -> [{}] {:<30} {gflops:>8.2} {unit} \
             ({} points measured)",
            cand.config.algorithm,
            cand.name(),
            conv_sweep.points_measured_for(op)
        );
    }

    if db.is_empty() {
        return Err("sweep produced an empty tuning DB".into());
    }
    // Under exhaustive search the algorithm axis must actually have been
    // swept: every 3x3/s1 conv problem measures all three native
    // algorithms — and, within Winograd, both `wino_m` tile sizes.  (A
    // budgeted strategy prunes by design, so the coverage contract only
    // binds the exhaustive run — CI runs both and compares.)
    let mut winos_swept: Vec<u32> = Vec::new();
    for op in conv_sweep.winners.keys() {
        let algs =
            conv_sweep.axis_values_for(op, |c| c.config.algorithm);
        let winos = conv_sweep.axis_values_for(op, |c| {
            (c.config.algorithm == ConvAlgorithm::Winograd)
                .then_some(c.config.wino_m)
        });
        if exhaustive && op.starts_with("conv_3x3s1") {
            for want in [
                ConvAlgorithm::Im2col,
                ConvAlgorithm::Tiled,
                ConvAlgorithm::Winograd,
            ] {
                if !algs.contains(&want) {
                    return Err(format!(
                        "{op}: algorithm {want} was never measured \
                         ({algs:?}) — the algorithm axis collapsed"
                    )
                    .into());
                }
            }
            for want in [2u32, 4] {
                if !winos.contains(&Some(want)) {
                    return Err(format!(
                        "{op}: winograd wino_m={want} was never \
                         measured — the wino_m axis collapsed"
                    )
                    .into());
                }
            }
        }
        let winos: Vec<u32> = winos.into_iter().flatten().collect();
        for &m in &winos {
            if !winos_swept.contains(&m) {
                winos_swept.push(m);
            }
        }
        println!(
            "  {op}: measured algorithms {algs:?}, wino_m {winos:?}"
        );
    }
    winos_swept.sort_unstable();
    // ... and so must the ISA axis, wherever the host supports more
    // than scalar.
    let mut isas_swept: Vec<Isa> = Vec::new();
    for op in gemm_sweep.winners.keys() {
        let swept = gemm_sweep.axis_values_for(op, |p| p.isa);
        if exhaustive {
            for isa in &isas {
                if !swept.contains(isa) {
                    return Err(format!(
                        "{op}: ISA {isa} was never measured ({swept:?}) — \
                         the ISA axis collapsed"
                    )
                    .into());
                }
            }
        }
        println!("  {op}: measured ISAs {swept:?}");
        for isa in swept {
            if !isas_swept.contains(&isa) {
                isas_swept.push(isa);
            }
        }
    }
    if exhaustive && isas.len() >= 2 && isas_swept.len() < 2 {
        return Err(format!(
            "host supports {isas:?} but the sweep measured only \
             {isas_swept:?} — the ISA axis collapsed"
        )
        .into());
    }
    // ... and the dtype axis: under exhaustive search every GEMM problem
    // measures both precisions, and every 3x3/s1 conv problem measures
    // the quantized im2col points (i8 exists only on the im2col
    // lowering, so conv problems off that domain legitimately stay f32).
    let mut dtypes_swept: Vec<Dtype> = Vec::new();
    let mut note_dtypes = |swept: &[Dtype]| {
        for &d in swept {
            if !dtypes_swept.contains(&d) {
                dtypes_swept.push(d);
            }
        }
    };
    for op in gemm_sweep.winners.keys() {
        let swept = gemm_sweep.axis_values_for(op, |p| p.dtype);
        if exhaustive {
            for want in Dtype::all() {
                if !swept.contains(&want) {
                    return Err(format!(
                        "{op}: dtype {want} was never measured \
                         ({swept:?}) — the dtype axis collapsed"
                    )
                    .into());
                }
            }
        }
        println!("  {op}: measured dtypes {swept:?}");
        note_dtypes(&swept);
    }
    for op in conv_sweep.winners.keys() {
        let swept = conv_sweep.axis_values_for(op, |c| c.dtype);
        if exhaustive && !swept.contains(&Dtype::I8) {
            return Err(format!(
                "{op}: dtype i8 was never measured ({swept:?}) — the \
                 conv dtype axis collapsed"
            )
            .into());
        }
        println!("  {op}: measured dtypes {swept:?}");
        note_dtypes(&swept);
    }
    dtypes_swept.sort_by_key(|d| d.as_str());
    // ... and the pack axis: under exhaustive search every GEMM problem
    // measures A-only and A+B panel packing, and every conv problem
    // measures both on its GEMM-lowered points (im2col applies
    // everywhere, so packed-B candidates exist for every conv problem;
    // direct tiled points are A-only by construction).
    let mut packs_swept: Vec<Pack> = Vec::new();
    let mut note_packs = |swept: &[Pack]| {
        for &p in swept {
            if !packs_swept.contains(&p) {
                packs_swept.push(p);
            }
        }
    };
    for op in gemm_sweep.winners.keys() {
        let swept = gemm_sweep.axis_values_for(op, |p| p.pack);
        if exhaustive {
            for want in Pack::all() {
                if !swept.contains(&want) {
                    return Err(format!(
                        "{op}: pack {want} was never measured ({swept:?}) \
                         — the pack axis collapsed"
                    )
                    .into());
                }
            }
        }
        println!("  {op}: measured packs {swept:?}");
        note_packs(&swept);
    }
    for op in conv_sweep.winners.keys() {
        let swept = conv_sweep.axis_values_for(op, |c| c.pack);
        if exhaustive {
            for want in Pack::all() {
                if !swept.contains(&want) {
                    return Err(format!(
                        "{op}: pack {want} was never measured ({swept:?}) \
                         — the conv pack axis collapsed"
                    )
                    .into());
                }
            }
        }
        println!("  {op}: measured packs {swept:?}");
        note_packs(&swept);
    }
    packs_swept.sort_by_key(|p| p.as_str());

    // Fold a previously written (possibly legacy) DB into the unified
    // schema, keeping the faster entry per key.
    if let Some(old_path) = merge_path {
        let old = SelectionDb::load(old_path)?;
        let stats = db.merge(&old);
        println!(
            "merged {} ({} entries): {} added, {} replaced, {} kept, \
             {} migrated to the unified schema, {} kind conflicts \
             (kept the fresh sweep's entry)",
            old_path.display(),
            old.len(),
            stats.added,
            stats.replaced,
            stats.kept,
            stats.migrated,
            stats.kind_conflicts
        );
    }

    // Persist + reload: the DB a deployment ships.
    let db_path = out_dir.join("tuning_host.json");
    db.save(&db_path)?;
    let loaded = SelectionDb::load(&db_path)?;
    println!(
        "tuning DB: {} selections -> {}",
        loaded.len(),
        db_path.display()
    );

    // Prove plan-time consultation: a fresh engine over the same store,
    // with the reloaded DB attached, must plan every swept artifact with
    // the persisted winner — for conv problems including the algorithm,
    // for GEMM problems including the ISA.
    let mut tuned_engine =
        NativeEngine::with_tuning(engine.store().clone(), loaded.clone());
    let names: Vec<String> =
        engine.store().iter().map(|m| m.name.clone()).collect();
    for name in &names {
        let meta = engine.store().get(name)?.clone();
        let Some(key) = selection_key_for(&meta, HOST_DEVICE) else {
            continue;
        };
        if let Some((want, _)) = loaded.get::<GemmPoint>(&key) {
            if meta.kind == "gemm" {
                let got = tuned_engine
                    .planned_gemm(name)?
                    .ok_or_else(|| format!("{name}: no gemm plan"))?;
                // Winners from this host's grid plan verbatim; a merged
                // off-host entry may legitimately degrade its ISA to
                // scalar, and an i8 winner degrades to f32 on an
                // artifact without quantization metadata — compare
                // against the same degrade ladder the planner applies.
                let mut want = want.host_degraded();
                if meta.quant.is_none() {
                    want = GemmPoint { dtype: Dtype::F32, ..want };
                }
                if got != want {
                    return Err(format!(
                        "{name}: engine planned {} but the tuned \
                         selection is {}",
                        got.name(),
                        want.name()
                    )
                    .into());
                }
                println!("  plan({name}) consults DB -> {}", got.name());
            }
        }
        if meta.kind == "conv" {
            if let Some((want_point, _)) = loaded.get::<ConvPoint>(&key) {
                let got_cfg = tuned_engine
                    .planned_conv(name)?
                    .ok_or_else(|| format!("{name}: no conv plan"))?;
                let got_blocked = tuned_engine.planned_params(name)?;
                if got_cfg != want_point.config
                    || got_blocked != want_point.blocked
                {
                    return Err(format!(
                        "{name}: engine planned [{}] {} but the tuned \
                         selection is [{}] {}",
                        got_cfg.algorithm,
                        got_cfg.name(),
                        want_point.config.algorithm,
                        want_point.config.name()
                    )
                    .into());
                }
                println!(
                    "  plan({name}) consults DB -> algorithm {} ({})",
                    got_cfg.algorithm,
                    got_cfg.name()
                );
            }
        }
    }

    // BENCH_ci.json: tuned vs default per problem.  The default points
    // are *pinned* into every strategy's proposals, so tuned >= default
    // is an invariant of the argmax, not a flaky timing assertion.  Conv
    // entries carry the chosen-algorithm and `wino_m` columns; conv and
    // GEMM entries alike carry the chosen-ISA column plus the best
    // *measured scalar* point (tuned >= scalar-best is the same argmax
    // invariant — the winner is the max over a superset of the measured
    // scalar rows).  Every entry carries `points_measured` so CI can
    // assert guided search's >=10x measured-point savings against the
    // exhaustive baseline.
    let default = GemmPoint::default();
    let conv_default = ConvPoint::default();
    let mut problems = Value::object();
    let mut worst_ratio = f64::INFINITY;
    let mut total_points = 0usize;
    let add_problem = |op: &str,
                           tuned_gf: f64,
                           default_gf: f64,
                           tuned_config: String,
                           points_measured: usize,
                           algorithm: Option<&str>,
                           wino_m: Option<u64>,
                           isa: Option<(&str, f64)>,
                           dtype: Option<(&str, Value)>,
                           pack: &str,
                           problems: &mut Value,
                           worst_ratio: &mut f64|
     -> Result<(), Box<dyn std::error::Error>> {
        if tuned_gf < default_gf {
            return Err(format!(
                "{op}: tuned {tuned_gf:.2} GF/s below default \
                 {default_gf:.2} GF/s"
            )
            .into());
        }
        let mut entry = Value::object();
        entry
            .set("default_gflops", default_gf)
            .set("tuned_gflops", tuned_gf)
            .set("tuned_config", tuned_config)
            .set("points_measured", points_measured as u64);
        if let Some(alg) = algorithm {
            entry.set("algorithm", alg);
        }
        if let Some(m) = wino_m {
            entry.set("wino_m", m);
        }
        if let Some((isa, scalar_gf)) = isa {
            if tuned_gf < scalar_gf {
                return Err(format!(
                    "{op}: tuned {tuned_gf:.2} GF/s below the scalar \
                     winner {scalar_gf:.2} GF/s"
                )
                .into());
            }
            entry.set("isa", isa).set("scalar_gflops", scalar_gf);
        }
        if let Some((dt, per_dtype)) = dtype {
            entry.set("dtype", dt).set("per_dtype", per_dtype);
        }
        entry.set("pack", pack);
        if default_gf > 0.0 {
            let ratio = tuned_gf / default_gf;
            entry.set("speedup", ratio);
            *worst_ratio = worst_ratio.min(ratio);
        }
        problems.set(op, entry);
        Ok(())
    };
    for (op, (point, tuned_gf)) in &gemm_sweep.winners {
        let default_gf =
            gemm_sweep.gflops_for(op, &default).unwrap_or(0.0);
        // Best measured scalar point for this problem: the baseline the
        // ISA axis is judged against.
        let scalar_gf = gemm_sweep
            .rows
            .iter()
            .filter(|r| {
                &r.problem == op && r.point.isa == Isa::Scalar
            })
            .map(|r| r.gflops)
            .fold(0.0f64, f64::max);
        if point.isa != Isa::Scalar {
            println!(
                "  {op}: ISA axis pays — [{}] {:.2} GF/s vs scalar \
                 winner {:.2} GF/s",
                point.isa, tuned_gf, scalar_gf
            );
        }
        let points = gemm_sweep.points_measured_for(op);
        total_points += points;
        let per_dtype =
            per_dtype_columns(&gemm_sweep.rows, op, &|p| p.dtype, &|p| {
                p.isa
            })?;
        add_problem(
            op,
            *tuned_gf,
            default_gf,
            point.name(),
            points,
            None,
            None,
            Some((point.isa.as_str(), scalar_gf)),
            Some((point.dtype.as_str(), per_dtype)),
            point.pack.as_str(),
            &mut problems,
            &mut worst_ratio,
        )?;
    }
    for (op, (cand, tuned_gf)) in &conv_sweep.winners {
        let default_gf =
            conv_sweep.gflops_for(op, &conv_default).unwrap_or(0.0);
        // Best measured scalar-ISA conv point: the same argmax baseline
        // the GEMM ISA column is judged against.
        let scalar_gf = conv_sweep
            .rows
            .iter()
            .filter(|r| {
                &r.problem == op && r.point.isa == Isa::Scalar
            })
            .map(|r| r.gflops)
            .fold(0.0f64, f64::max);
        let points = conv_sweep.points_measured_for(op);
        total_points += points;
        let per_dtype =
            per_dtype_columns(&conv_sweep.rows, op, &|c| c.dtype, &|c| {
                c.isa
            })?;
        add_problem(
            op,
            *tuned_gf,
            default_gf,
            cand.name(),
            points,
            Some(cand.config.algorithm.as_str()),
            Some(cand.config.wino_m as u64),
            Some((cand.isa.as_str(), scalar_gf)),
            Some((cand.dtype.as_str(), per_dtype)),
            cand.pack.as_str(),
            &mut problems,
            &mut worst_ratio,
        )?;
    }
    // The quantization acceptance head-to-head: tuned int8 vs tuned f32
    // at 512^3, compared in elements/second (the unit that is common to
    // both precisions — GFLOP/s vs GOP/s would compare apples to
    // oranges).  Each side runs its best measured point; the i8 side
    // times the full end-to-end path the engine executes — quantize,
    // widening GEMM, dequantize epilogue — so the ratio is what a
    // deployment actually gains.  On hosts with AVX2 the widening
    // `_mm256_madd_epi16` kernel must deliver >= 2x; scalar-only hosts
    // record the ratio without asserting (the scalar widening loop has
    // no lane-width advantage to exploit).
    let best_point_for = |d: Dtype| -> Option<GemmPoint> {
        gemm_sweep
            .rows
            .iter()
            .filter(|r| r.point.dtype == d)
            .max_by(|x, y| x.gflops.total_cmp(&y.gflops))
            .map(|r| r.point)
    };
    let f32_pt = best_point_for(Dtype::F32)
        .unwrap_or_default()
        .host_degraded();
    let i8_pt = best_point_for(Dtype::I8)
        .unwrap_or(GemmPoint { dtype: Dtype::I8, ..f32_pt })
        .host_degraded();
    let (hm, hn, hk) = (512usize, 512, 512);
    let hops = 2 * (hm * hn * hk) as u64;
    let mut rng = XorShift::new(4242);
    let ha = rng.f32_vec(hm * hk);
    let hb = rng.f32_vec(hk * hn);
    let hq = QuantParams { scale: 1.0 / 256.0, zero_point: 0 };
    let h2h_iters = if quick { 3 } else { 5 };
    let sf = bench("gemm_f32_512^3 (tuned)", 1, h2h_iters, || {
        black_box(gemm_blocked_isa(
            &ha, &hb, hm, hn, hk, &f32_pt.params, f32_pt.isa,
        ));
    });
    let si = bench("gemm_i8_512^3 (tuned, end-to-end)", 1, h2h_iters, || {
        let aq = quantize_slice(&ha, &hq);
        let bq = quantize_slice(&hb, &hq);
        black_box(gemm_i8_dequant(
            &aq, &bq, hm, hn, hk, &hq, &hq, &i8_pt.params, i8_pt.isa,
        ));
    });
    println!("== int8 head-to-head at 512^3 ==");
    println!("{}", sf.line(Some(hops)));
    println!("{}", si.line_int(Some(hops)));
    let elems = (hm * hn * hk) as f64;
    let eps = |min_secs: f64| {
        if min_secs <= 0.0 { 0.0 } else { elems / min_secs }
    };
    let eps_f32 = eps(sf.min.as_secs_f64());
    let eps_i8 = eps(si.min.as_secs_f64());
    let i8_speedup =
        if eps_f32 > 0.0 { eps_i8 / eps_f32 } else { 0.0 };
    println!(
        "  [{}] {} vs [{}] {}: int8 {:.3e} elems/s, f32 {:.3e} elems/s \
         -> {:.2}x",
        i8_pt.isa,
        i8_pt.name(),
        f32_pt.isa,
        f32_pt.name(),
        eps_i8,
        eps_f32,
        i8_speedup
    );
    let have_avx2 = isas.contains(&Isa::Avx2);
    if have_avx2 && i8_speedup < 2.0 {
        return Err(format!(
            "int8 head-to-head at 512^3: {i8_speedup:.2}x below the 2x \
             elements/second bar the AVX2 widening kernel must clear \
             (i8 {eps_i8:.3e} vs f32 {eps_f32:.3e} elems/s)"
        )
        .into());
    }
    let mut h2h = Value::object();
    h2h.set("m", hm as u64)
        .set("n", hn as u64)
        .set("k", hk as u64)
        .set("f32_point", f32_pt.name())
        .set("i8_point", i8_pt.name())
        .set("f32_elems_per_s", eps_f32)
        .set("i8_elems_per_s", eps_i8)
        .set("i8_speedup", i8_speedup)
        .set("asserted", have_avx2);

    // The pack-axis acceptance head-to-head: the best measured A-only
    // point against the best measured A+B point, each re-timed at 512^3
    // through `gemm_blocked_ex` with a prewarmed arena.  At this size
    // the k-panels of B are revisited once per row band, which is
    // exactly the reuse B-panel packing monetizes — CI asserts the
    // tuned-ab side does not lose to tuned-a.
    let best_packed = |pk: Pack| -> GemmPoint {
        gemm_sweep
            .rows
            .iter()
            .filter(|r| r.point.dtype == Dtype::F32 && r.point.pack == pk)
            .max_by(|x, y| x.gflops.total_cmp(&y.gflops))
            .map(|r| r.point)
            .unwrap_or(GemmPoint { pack: pk, ..GemmPoint::default() })
            .host_degraded()
    };
    let scratch = Scratch::new();
    println!("== pack head-to-head at 512^3 ==");
    let mut pack_h2h = Value::object();
    pack_h2h.set("m", hm as u64).set("n", hn as u64).set("k", hk as u64);
    let mut pack_gflops = [0.0f64; 2];
    for (slot, pk) in Pack::all().into_iter().enumerate() {
        let pt = best_packed(pk);
        scratch.prewarm(&gemm_workspace(hm, hn, hk, &pt.params, pk));
        let s = bench(
            &format!("gemm_512^3 (tuned, pack {pk})"),
            1,
            h2h_iters,
            || {
                black_box(gemm_blocked_ex(
                    &ha, &hb, hm, hn, hk, &pt.params, pt.isa, pk,
                    &scratch,
                ));
            },
        );
        println!("{}", s.line(Some(hops)));
        pack_gflops[slot] = s.gflops(hops);
        pack_h2h
            .set(&format!("{pk}_point"), pt.name())
            .set(&format!("{pk}_gflops"), s.gflops(hops));
    }
    let pack_speedup = if pack_gflops[0] > 0.0 {
        pack_gflops[1] / pack_gflops[0]
    } else {
        0.0
    };
    println!(
        "  pack ab vs pack a at 512^3: {:.2} vs {:.2} GFLOP/s -> {:.2}x",
        pack_gflops[1], pack_gflops[0], pack_speedup
    );
    pack_h2h.set("ab_speedup", pack_speedup);

    let mut bench = Value::object();
    let isa_strs = |list: &[Isa]| -> Value {
        Value::Array(
            list.iter().map(|i| Value::Str(i.as_str().into())).collect(),
        )
    };
    bench
        .set("platform", engine.platform())
        .set("device", HOST_DEVICE)
        .set("mode", mode)
        .set("search", search)
        .set("budget", budget as u64)
        .set("grid_points", grid.len())
        .set("conv_grid_points", conv_grid.len())
        .set("points_measured", total_points as u64)
        .set("isas_detected", isa_strs(&isas))
        .set("isas_swept", isa_strs(&isas_swept))
        .set(
            "dtypes_swept",
            Value::Array(
                dtypes_swept
                    .iter()
                    .map(|d| Value::Str(d.as_str().into()))
                    .collect(),
            ),
        )
        .set(
            "packs_swept",
            Value::Array(
                packs_swept
                    .iter()
                    .map(|p| Value::Str(p.as_str().into()))
                    .collect(),
            ),
        )
        .set("int8_head_to_head", h2h)
        .set("pack_head_to_head", pack_h2h)
        .set(
            "conv_wino_swept",
            Value::Array(
                winos_swept.iter().map(|&m| Value::from(m)).collect(),
            ),
        )
        .set("iters", iters)
        .set("problems", problems);
    let bench_path = out_dir.join("BENCH_ci.json");
    std::fs::write(&bench_path, bench.to_json_pretty())?;
    println!("gflops summary -> {}", bench_path.display());
    if worst_ratio.is_finite() {
        println!("worst tuned/default speedup: {worst_ratio:.2}x");
    }
    println!(
        "OK [{search}]: {total_points} points measured across {} + {} \
         grid points; tuned >= default (and >= the measured scalar \
         winner, per dtype) for every problem; DB (incl. algorithm, \
         isa, dtype + pack) consulted at plan time; int8 512^3 \
         head-to-head {:.2}x; pack ab/a 512^3 {:.2}x",
        grid.len(),
        conv_grid.len(),
        i8_speedup,
        pack_speedup
    );
    Ok(())
}
