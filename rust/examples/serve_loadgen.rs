//! Synthetic load generator for the multi-actor serving layer.
//!
//! Builds a synthetic manifest zoo (mixed GEMM + conv shapes), spawns an
//! `EnginePool` per configured size, and drives it either from M
//! **closed-loop** client threads (each waits for its response before
//! issuing the next request) or in **open-loop** mode (`--open-loop
//! RATE`: arrivals at a fixed rate regardless of completions, submitted
//! through `try_submit_run` so overload sheds as `Busy` instead of
//! queueing unboundedly).  Reports throughput, latency percentiles, and
//! — in open-loop mode — the shed rate, the pool's backpressure signal
//! under a load it cannot absorb.
//!
//! ```sh
//! cargo run --release --example serve_loadgen                  # sweep
//! cargo run --release --example serve_loadgen -- --smoke       # CI gate
//! cargo run --release --example serve_loadgen -- \
//!     --pools 1,2,4 --clients 8 --requests 60 --threads 1 --out reports
//! cargo run --release --example serve_loadgen -- \
//!     --open-loop 500 --pools 1,2 --requests 100   # 500 arrivals/s
//! ```
//!
//! `--smoke` runs pool sizes 1 and 2 on the closed-loop contention
//! workload and **exits non-zero unless pool(2) throughput >=
//! --assert-speedup × pool(1)** (default 1.0) — the CI `serve-smoke`
//! contract.  All modes write `<out>/serve_loadgen.csv`, with a `mode`
//! column and shed accounting (always 0 for closed-loop rows).

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use portable_kernels::blas::BlockedParams;
use portable_kernels::coordinator::{
    EngineClient, EnginePool, PoolConfig, RunTicket, SubmitError,
};
use portable_kernels::runtime::{ArtifactStore, NativeEngine};
use portable_kernels::util::rng::XorShift;
use portable_kernels::util::tmp::TempDir;

/// One synthetic square GEMM manifest entry.
fn gemm_entry(name: &str, m: usize) -> String {
    let flops = 2 * (m as u64).pow(3);
    format!(
        r#"{{"name": "{name}", "kind": "gemm", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops},
            "m": {m}, "n": {m}, "k": {m}, "groups": ["gemm"],
            "inputs": [{{"shape": [{m}, {m}], "dtype": "float32"}},
                       {{"shape": [{m}, {m}], "dtype": "float32"}}]}}"#
    )
}

/// One synthetic SAME-padded conv manifest entry.
fn conv_entry(name: &str, batch: usize, h: usize, c: usize, k: usize) -> String {
    let flops = 2 * (batch * h * h * k * 9 * c) as u64;
    format!(
        r#"{{"name": "{name}", "kind": "conv", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops}, "batch": {batch},
            "algorithm": "im2col", "groups": ["conv"],
            "layer": {{"name": "{name}", "window": 3, "stride": 1,
                       "in_h": {h}, "in_w": {h}, "in_c": {c}, "out_c": {k},
                       "out_h": {h}, "out_w": {h}, "padding": "SAME",
                       "flops": {flops}}},
            "inputs": [{{"shape": [{batch}, {h}, {h}, {c}], "dtype": "float32"}},
                       {{"shape": [3, 3, {c}, {k}], "dtype": "float32"}}]}}"#
    )
}

/// The serving zoo: shapes big enough that one request is real work
/// (~0.5-5 ms serial), varied enough that routing spreads them.
fn write_zoo(dir: &Path) {
    let entries = [
        gemm_entry("serve_gemm_96", 96),
        gemm_entry("serve_gemm_128", 128),
        gemm_entry("serve_gemm_160", 160),
        gemm_entry("serve_gemm_192", 192),
        conv_entry("serve_conv_16", 2, 16, 8, 16),
        conv_entry("serve_conv_24", 2, 24, 8, 16),
    ];
    std::fs::write(
        dir.join("manifest.json"),
        format!(
            r#"{{"version": 1, "artifacts": [{}]}}"#,
            entries.join(",\n")
        ),
    )
    .unwrap();
}

/// One measured cell of the sweep.
struct Cell {
    /// "closed" (M waiting clients) or "open" (fixed arrival rate).
    mode: &'static str,
    pool: usize,
    clients: usize,
    threads: usize,
    queue_depth: usize,
    /// Arrivals (open loop) or issued requests (closed loop).
    requests: usize,
    /// Open-loop target arrival rate (0 for closed loop).
    target_rps: f64,
    /// Arrivals rejected with `Busy` (always 0 for closed loop).
    shed: usize,
    wall_s: f64,
    rps: f64,
    p50_ms: f64,
    p95_ms: f64,
}

impl Cell {
    fn csv_header() -> &'static str {
        "mode,pool,clients,threads,queue_depth,requests,target_rps,shed,\
         shed_rate,wall_s,throughput_rps,p50_ms,p95_ms"
    }

    fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.shed as f64 / self.requests as f64
        }
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{:.2},{},{:.4},{:.6},{:.2},{:.4},{:.4}",
            self.mode,
            self.pool,
            self.clients,
            self.threads,
            self.queue_depth,
            self.requests,
            self.target_rps,
            self.shed,
            self.shed_rate(),
            self.wall_s,
            self.rps,
            self.p50_ms,
            self.p95_ms
        )
    }
}

fn percentile_ms(sorted: &[Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx].as_secs_f64() * 1e3
}

/// Drive one (pool size, clients, threads) cell: M closed-loop client
/// threads, each issuing `requests_per_client` blocking runs over a
/// seeded-random artifact mix.
fn run_cell(
    store: &ArtifactStore,
    pool_size: usize,
    clients: usize,
    threads: usize,
    queue_depth: usize,
    requests_per_client: usize,
) -> Result<Cell, Box<dyn std::error::Error>> {
    let config = PoolConfig {
        actors: pool_size,
        queue_depth,
        spill_depth: (queue_depth / 2).max(1),
        ..Default::default()
    };
    let actor_store = store.clone();
    let params = BlockedParams { threads, ..BlockedParams::default() };
    let pool = EnginePool::spawn_with(config, move |_| {
        Ok(NativeEngine::with_params(actor_store.clone(), params))
    })?;

    let names: Vec<String> = store.iter().map(|m| m.name.clone()).collect();
    let mut inputs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(names.len());
    for name in &names {
        inputs.push(pool.synth_inputs(name, 17)?);
        pool.warm(name)?;
    }

    let t0 = Instant::now();
    let mut latencies: Vec<Duration> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let pool = &pool;
                let names = &names;
                let inputs = &inputs;
                s.spawn(move || {
                    let mut rng = XorShift::new(0x5eed + c as u64);
                    let mut lat = Vec::with_capacity(requests_per_client);
                    for _ in 0..requests_per_client {
                        let i =
                            (rng.next_u64() % names.len() as u64) as usize;
                        let t = Instant::now();
                        let out =
                            pool.run(&names[i], inputs[i].clone()).unwrap();
                        lat.push(t.elapsed());
                        assert!(!out.outputs[0].is_empty());
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            latencies.extend(h.join().expect("client thread panicked"));
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    pool.shutdown();

    latencies.sort();
    let requests = clients * requests_per_client;
    Ok(Cell {
        mode: "closed",
        pool: pool_size,
        clients,
        threads,
        queue_depth,
        requests,
        target_rps: 0.0,
        shed: 0,
        wall_s: wall,
        rps: requests as f64 / wall,
        p50_ms: percentile_ms(&latencies, 0.50),
        p95_ms: percentile_ms(&latencies, 0.95),
    })
}

/// Drive one open-loop cell: arrivals at a fixed `rate` (requests/s)
/// submitted through `try_submit_run` — the non-blocking, backpressured
/// path — with `Busy` rejections counted as shed load rather than
/// queued.  `collectors` threads wait on the accepted tickets so
/// completion latency is measured without the dispatcher ever blocking.
fn run_cell_open(
    store: &ArtifactStore,
    pool_size: usize,
    collectors: usize,
    threads: usize,
    queue_depth: usize,
    arrivals: usize,
    rate: f64,
) -> Result<Cell, Box<dyn std::error::Error>> {
    let config = PoolConfig {
        actors: pool_size,
        queue_depth,
        spill_depth: (queue_depth / 2).max(1),
        ..Default::default()
    };
    let actor_store = store.clone();
    let params = BlockedParams { threads, ..BlockedParams::default() };
    let pool = EnginePool::spawn_with(config, move |_| {
        Ok(NativeEngine::with_params(actor_store.clone(), params))
    })?;

    let names: Vec<String> = store.iter().map(|m| m.name.clone()).collect();
    let mut inputs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(names.len());
    for name in &names {
        inputs.push(pool.synth_inputs(name, 17)?);
        pool.warm(name)?;
    }

    let mut shed = 0usize;
    let mut latencies: Vec<Duration> = Vec::new();
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<(), Box<dyn std::error::Error>> {
        // One shared FIFO of accepted tickets: whichever collector is
        // free takes the oldest outstanding ticket.  (Round-robin
        // pre-assignment would park fast tickets behind a slow one on
        // the same collector and inflate the recorded percentiles; with
        // a shared queue a ticket only waits when *every* collector is
        // busy on an older ticket, which is the FIFO-optimal order.)
        let (tx, rx) = mpsc::channel::<(RunTicket, Instant)>();
        let rx = std::sync::Mutex::new(rx);
        let mut handles = Vec::new();
        for _ in 0..collectors.max(1) {
            let rx = &rx;
            handles.push(s.spawn(move || {
                let mut lat = Vec::new();
                loop {
                    // Holding the lock across recv is intended: at most
                    // one collector parks on an empty queue; the rest
                    // queue on the mutex and each wakes for the next
                    // ticket as soon as it is free.
                    let msg = rx.lock().expect("collector lock").recv();
                    match msg {
                        Ok((ticket, submitted)) => {
                            ticket.wait().expect("accepted request failed");
                            lat.push(submitted.elapsed());
                        }
                        Err(_) => break,
                    }
                }
                lat
            }));
        }
        let mut rng = XorShift::new(0x0bea);
        for i in 0..arrivals {
            // Fixed arrival schedule, independent of completions — the
            // defining property of an open loop.
            let due = t0 + Duration::from_secs_f64(i as f64 / rate);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            let idx = (rng.next_u64() % names.len() as u64) as usize;
            match pool.try_submit_run(&names[idx], inputs[idx].clone()) {
                Ok(ticket) => {
                    tx.send((ticket, Instant::now()))
                        .expect("collector gone");
                }
                Err(SubmitError::Busy) => shed += 1,
                Err(SubmitError::Engine(e)) => return Err(e.into()),
            }
        }
        drop(tx);
        for h in handles {
            latencies.extend(h.join().expect("collector panicked"));
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    pool.shutdown();

    latencies.sort();
    let served = arrivals - shed;
    Ok(Cell {
        mode: "open",
        pool: pool_size,
        clients: collectors,
        threads,
        queue_depth,
        requests: arrivals,
        target_rps: rate,
        shed,
        wall_s: wall,
        rps: served as f64 / wall,
        p50_ms: percentile_ms(&latencies, 0.50),
        p95_ms: percentile_ms(&latencies, 0.95),
    })
}

fn parse_pools(spec: &str) -> Result<Vec<usize>, Box<dyn std::error::Error>> {
    let pools: Result<Vec<usize>, _> =
        spec.split(',').map(|s| s.trim().parse::<usize>()).collect();
    let pools = pools.map_err(|_| format!("bad --pools list {spec:?}"))?;
    if pools.is_empty() || pools.contains(&0) {
        return Err(format!("--pools needs positive sizes, got {spec:?}").into());
    }
    Ok(pools)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut pools: Vec<usize> = vec![1, 2];
    let mut clients = 8usize;
    let mut requests = 40usize;
    let mut threads = 1usize;
    let mut queue_depth = 64usize;
    let mut out_dir = PathBuf::from("reports");
    let mut smoke = false;
    let mut assert_speedup: Option<f64> = None;
    let mut open_loop: Option<f64> = None;

    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        let mut value = |what: &str| {
            it.next().ok_or_else(|| format!("{what} needs a value"))
        };
        match arg.as_str() {
            "--pools" => pools = parse_pools(&value("--pools")?)?,
            "--clients" => clients = value("--clients")?.parse()?,
            "--requests" => requests = value("--requests")?.parse()?,
            "--threads" => threads = value("--threads")?.parse()?,
            "--depth" => queue_depth = value("--depth")?.parse()?,
            "--out" => out_dir = PathBuf::from(value("--out")?),
            "--smoke" => smoke = true,
            "--assert-speedup" => {
                assert_speedup = Some(value("--assert-speedup")?.parse()?)
            }
            "--open-loop" => {
                let rate: f64 = value("--open-loop")?.parse()?;
                if rate <= 0.0 || !rate.is_finite() {
                    return Err("--open-loop needs a positive rate".into());
                }
                open_loop = Some(rate);
            }
            other => {
                return Err(format!(
                    "unknown argument {other:?}; usage: serve_loadgen \
                     [--pools 1,2,..] [--clients M] [--requests R] \
                     [--threads T] [--depth D] [--out DIR] [--smoke] \
                     [--assert-speedup X] [--open-loop RATE]"
                )
                .into())
            }
        }
    }
    if smoke {
        // The CI contract: pool sizes 1 and 2 on one contention
        // workload, serial kernels so pool width is the only
        // parallelism axis.  The contract is closed-loop by definition.
        if open_loop.is_some() {
            return Err("--smoke and --open-loop are exclusive".into());
        }
        pools = vec![1, 2];
        threads = 1;
    }

    let zoo = TempDir::new("serve-loadgen")?;
    write_zoo(zoo.path());
    let store = ArtifactStore::open(zoo.path())?;
    match open_loop {
        Some(rate) => println!(
            "== serve_loadgen (open loop): {} artifacts, {} arrivals at \
             {rate} req/s, threads={threads}, pools {pools:?} ==",
            store.len(),
            clients * requests
        ),
        None => println!(
            "== serve_loadgen: {} artifacts, {clients} clients x \
             {requests} requests, threads={threads}, pools {pools:?} ==",
            store.len()
        ),
    }

    let mut cells: Vec<Cell> = Vec::new();
    for &pool_size in &pools {
        let cell = match open_loop {
            Some(rate) => run_cell_open(
                &store,
                pool_size,
                clients,
                threads,
                queue_depth,
                clients * requests,
                rate,
            )?,
            None => run_cell(
                &store, pool_size, clients, threads, queue_depth, requests,
            )?,
        };
        println!(
            "pool={:<2} threads={threads}: {:>8.1} req/s  p50 {:>7.2} ms  \
             p95 {:>7.2} ms  shed {:>4} ({:>5.1}%)  (wall {:.2} s, {} \
             {})",
            cell.pool,
            cell.rps,
            cell.p50_ms,
            cell.p95_ms,
            cell.shed,
            cell.shed_rate() * 100.0,
            cell.wall_s,
            cell.requests,
            if cell.mode == "open" { "arrivals" } else { "requests" }
        );
        cells.push(cell);
    }

    std::fs::create_dir_all(&out_dir)?;
    let csv_path = out_dir.join("serve_loadgen.csv");
    let mut csv = String::from(Cell::csv_header());
    csv.push('\n');
    for cell in &cells {
        csv.push_str(&cell.csv_row());
        csv.push('\n');
    }
    std::fs::write(&csv_path, csv)?;
    println!("wrote {}", csv_path.display());

    if smoke {
        let min_speedup = assert_speedup.unwrap_or(1.0);
        let single = cells
            .iter()
            .find(|c| c.pool == 1)
            .ok_or("smoke needs the pool=1 cell")?;
        let pooled = cells
            .iter()
            .find(|c| c.pool == 2)
            .ok_or("smoke needs the pool=2 cell")?;
        let ratio = pooled.rps / single.rps;
        println!(
            "smoke: pool(2) / pool(1) throughput = {ratio:.2}x \
             (required >= {min_speedup:.2}x)"
        );
        if ratio < min_speedup {
            return Err(format!(
                "serving smoke failed: pool(2) at {:.1} req/s is only \
                 {ratio:.2}x pool(1) at {:.1} req/s (need >= \
                 {min_speedup:.2}x): scale-out must not lose throughput \
                 under contention",
                pooled.rps, single.rps
            )
            .into());
        }
        println!("OK: pool(2) sustains >= {min_speedup:.2}x single-actor throughput");
    }
    Ok(())
}
