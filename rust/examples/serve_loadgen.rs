//! Synthetic load generator for the multi-actor serving layer.
//!
//! Builds a synthetic manifest zoo (mixed GEMM + conv shapes), spawns an
//! `EnginePool` per configured size, and drives it either from M
//! **closed-loop** client threads (each waits for its response before
//! issuing the next request) or in **open-loop** mode (`--open-loop
//! RATE`: arrivals at a fixed rate regardless of completions, submitted
//! through `try_submit_run` so overload sheds as `Busy` instead of
//! queueing unboundedly).  Reports throughput, latency percentiles, and
//! — in open-loop mode — the shed rate, the pool's backpressure signal
//! under a load it cannot absorb.
//!
//! ```sh
//! cargo run --release --example serve_loadgen                  # sweep
//! cargo run --release --example serve_loadgen -- --smoke       # CI gate
//! cargo run --release --example serve_loadgen -- \
//!     --pools 1,2,4 --clients 8 --requests 60 --threads 1 --out reports
//! cargo run --release --example serve_loadgen -- \
//!     --open-loop 500 --pools 1,2 --requests 100   # 500 arrivals/s
//! ```
//!
//! `--smoke` runs pool sizes 1 and 2 on the closed-loop contention
//! workload and **exits non-zero unless pool(2) throughput >=
//! --assert-speedup × pool(1)** (default 1.0) **and steady-state arena
//! growth is zero** (after warmup prewarms every plan's worst-case
//! workspace, serving must not allocate kernel scratch) — the CI
//! `serve-smoke` contract.  All modes write `<out>/serve_loadgen.csv`,
//! with a `mode` column, shed accounting (always 0 for closed-loop
//! rows), and per-cell arena counters (`scratch_hits`, `scratch_grows`,
//! `steady_grows`, `scratch_high_water_bytes`).
//!
//! `--phase-shift` runs the **online re-tuning** demonstration instead:
//! a pool serves a steady mix, traffic then shifts onto a shape class
//! whose seeded selection is deliberately poisoned (throughput craters),
//! the measured re-tuner promotes a better point from live hot-class
//! accounting, and [`EnginePool::swap_tuning`] broadcasts the new epoch
//! into the serving pool without a restart.  With `--assert-recovery R`
//! the run **exits non-zero unless post-re-tune throughput >= R × the
//! pre-shift steady state** — the CI recovery contract.
//!
//! ```sh
//! cargo run --release --example serve_loadgen -- \
//!     --phase-shift --assert-recovery 0.9 --out reports
//! ```

use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use portable_kernels::blas::BlockedParams;
use portable_kernels::config::GemmPoint;
use portable_kernels::coordinator::{
    EngineClient, EnginePool, PoolConfig, RunTicket, SubmitError,
};
use portable_kernels::runtime::{ArtifactStore, NativeEngine, HOST_DEVICE};
use portable_kernels::tuner::{
    retune_native, RetuneConfig, SelectionDb, SelectionKey, TuningHandle,
};
use portable_kernels::util::rng::XorShift;
use portable_kernels::util::scratch::ScratchStats;
use portable_kernels::util::tmp::TempDir;

/// One synthetic square GEMM manifest entry.
fn gemm_entry(name: &str, m: usize) -> String {
    let flops = 2 * (m as u64).pow(3);
    format!(
        r#"{{"name": "{name}", "kind": "gemm", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops},
            "m": {m}, "n": {m}, "k": {m}, "groups": ["gemm"],
            "inputs": [{{"shape": [{m}, {m}], "dtype": "float32"}},
                       {{"shape": [{m}, {m}], "dtype": "float32"}}]}}"#
    )
}

/// One synthetic SAME-padded conv manifest entry.
fn conv_entry(name: &str, batch: usize, h: usize, c: usize, k: usize) -> String {
    let flops = 2 * (batch * h * h * k * 9 * c) as u64;
    format!(
        r#"{{"name": "{name}", "kind": "conv", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops}, "batch": {batch},
            "algorithm": "im2col", "groups": ["conv"],
            "layer": {{"name": "{name}", "window": 3, "stride": 1,
                       "in_h": {h}, "in_w": {h}, "in_c": {c}, "out_c": {k},
                       "out_h": {h}, "out_w": {h}, "padding": "SAME",
                       "flops": {flops}}},
            "inputs": [{{"shape": [{batch}, {h}, {h}, {c}], "dtype": "float32"}},
                       {{"shape": [3, 3, {c}, {k}], "dtype": "float32"}}]}}"#
    )
}

/// The serving zoo: shapes big enough that one request is real work
/// (~0.5-5 ms serial), varied enough that routing spreads them.
fn write_zoo(dir: &Path) {
    let entries = [
        gemm_entry("serve_gemm_96", 96),
        gemm_entry("serve_gemm_128", 128),
        gemm_entry("serve_gemm_160", 160),
        gemm_entry("serve_gemm_192", 192),
        conv_entry("serve_conv_16", 2, 16, 8, 16),
        conv_entry("serve_conv_24", 2, 24, 8, 16),
    ];
    std::fs::write(
        dir.join("manifest.json"),
        format!(
            r#"{{"version": 1, "artifacts": [{}]}}"#,
            entries.join(",\n")
        ),
    )
    .unwrap();
}

/// One measured cell of the sweep.
struct Cell {
    /// "closed" (M waiting clients) or "open" (fixed arrival rate).
    mode: &'static str,
    pool: usize,
    clients: usize,
    threads: usize,
    queue_depth: usize,
    /// Arrivals (open loop) or issued requests (closed loop).
    requests: usize,
    /// Open-loop target arrival rate (0 for closed loop).
    target_rps: f64,
    /// Arrivals rejected with `Busy` (always 0 for closed loop).
    shed: usize,
    wall_s: f64,
    rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    /// Kernel-scratch arena checkouts served from pooled buffers during
    /// this cell's workload (summed across pool actors).
    scratch_hits: u64,
    /// Total arena growth allocations since the pool spawned, warmup
    /// prewarming included.
    scratch_grows: u64,
    /// Arena growth allocations during the measured workload itself —
    /// 0 is the zero-allocation steady-state invariant the serving
    /// smoke gate asserts.
    steady_grows: u64,
    /// Arena high-water mark in bytes, summed across pool actors.
    scratch_high_water: u64,
}

impl Cell {
    fn csv_header() -> &'static str {
        "mode,pool,clients,threads,queue_depth,requests,target_rps,shed,\
         shed_rate,wall_s,throughput_rps,p50_ms,p95_ms,\
         scratch_hits,scratch_grows,steady_grows,scratch_high_water_bytes"
    }

    fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.shed as f64 / self.requests as f64
        }
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{:.2},{},{:.4},{:.6},{:.2},{:.4},{:.4},\
             {},{},{},{}",
            self.mode,
            self.pool,
            self.clients,
            self.threads,
            self.queue_depth,
            self.requests,
            self.target_rps,
            self.shed,
            self.shed_rate(),
            self.wall_s,
            self.rps,
            self.p50_ms,
            self.p95_ms,
            self.scratch_hits,
            self.scratch_grows,
            self.steady_grows,
            self.scratch_high_water
        )
    }
}

fn percentile_ms(sorted: &[Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx].as_secs_f64() * 1e3
}

/// Drive one (pool size, clients, threads) cell: M closed-loop client
/// threads, each issuing `requests_per_client` blocking runs over a
/// seeded-random artifact mix.
fn run_cell(
    store: &ArtifactStore,
    pool_size: usize,
    clients: usize,
    threads: usize,
    queue_depth: usize,
    requests_per_client: usize,
) -> Result<Cell, Box<dyn std::error::Error>> {
    let config = PoolConfig {
        actors: pool_size,
        queue_depth,
        spill_depth: (queue_depth / 2).max(1),
        ..Default::default()
    };
    let actor_store = store.clone();
    let params = BlockedParams { threads, ..BlockedParams::default() };
    let pool = EnginePool::spawn_with(config, move |_| {
        Ok(NativeEngine::with_params(actor_store.clone(), params))
    })?;

    let names: Vec<String> = store.iter().map(|m| m.name.clone()).collect();
    let mut inputs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(names.len());
    for name in &names {
        inputs.push(pool.synth_inputs(name, 17)?);
        pool.warm(name)?;
    }
    // Arena baseline after warmup: every plan has prewarmed its
    // worst-case workspace, so growth from here on breaks the
    // zero-allocation steady-state invariant.
    let warmed = pool.stats().scratch;

    let t0 = Instant::now();
    let mut latencies: Vec<Duration> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let pool = &pool;
                let names = &names;
                let inputs = &inputs;
                s.spawn(move || {
                    let mut rng = XorShift::new(0x5eed + c as u64);
                    let mut lat = Vec::with_capacity(requests_per_client);
                    for _ in 0..requests_per_client {
                        let i =
                            (rng.next_u64() % names.len() as u64) as usize;
                        let t = Instant::now();
                        let out =
                            pool.run(&names[i], inputs[i].clone()).unwrap();
                        lat.push(t.elapsed());
                        assert!(!out.outputs[0].is_empty());
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            latencies.extend(h.join().expect("client thread panicked"));
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let scratch = pool.stats().scratch;
    pool.shutdown();

    latencies.sort();
    let requests = clients * requests_per_client;
    Ok(Cell {
        mode: "closed",
        pool: pool_size,
        clients,
        threads,
        queue_depth,
        requests,
        target_rps: 0.0,
        shed: 0,
        wall_s: wall,
        rps: requests as f64 / wall,
        p50_ms: percentile_ms(&latencies, 0.50),
        p95_ms: percentile_ms(&latencies, 0.95),
        scratch_hits: scratch.hits.saturating_sub(warmed.hits),
        scratch_grows: scratch.grows,
        steady_grows: scratch.grows.saturating_sub(warmed.grows),
        scratch_high_water: scratch.high_water_bytes,
    })
}

/// Drive one open-loop cell: arrivals at a fixed `rate` (requests/s)
/// submitted through `try_submit_run` — the non-blocking, backpressured
/// path — with `Busy` rejections counted as shed load rather than
/// queued.  `collectors` threads wait on the accepted tickets so
/// completion latency is measured without the dispatcher ever blocking.
fn run_cell_open(
    store: &ArtifactStore,
    pool_size: usize,
    collectors: usize,
    threads: usize,
    queue_depth: usize,
    arrivals: usize,
    rate: f64,
) -> Result<Cell, Box<dyn std::error::Error>> {
    let config = PoolConfig {
        actors: pool_size,
        queue_depth,
        spill_depth: (queue_depth / 2).max(1),
        ..Default::default()
    };
    let actor_store = store.clone();
    let params = BlockedParams { threads, ..BlockedParams::default() };
    let pool = EnginePool::spawn_with(config, move |_| {
        Ok(NativeEngine::with_params(actor_store.clone(), params))
    })?;

    let names: Vec<String> = store.iter().map(|m| m.name.clone()).collect();
    let mut inputs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(names.len());
    for name in &names {
        inputs.push(pool.synth_inputs(name, 17)?);
        pool.warm(name)?;
    }
    let warmed = pool.stats().scratch;

    let mut shed = 0usize;
    let mut latencies: Vec<Duration> = Vec::new();
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<(), Box<dyn std::error::Error>> {
        // One shared FIFO of accepted tickets: whichever collector is
        // free takes the oldest outstanding ticket.  (Round-robin
        // pre-assignment would park fast tickets behind a slow one on
        // the same collector and inflate the recorded percentiles; with
        // a shared queue a ticket only waits when *every* collector is
        // busy on an older ticket, which is the FIFO-optimal order.)
        let (tx, rx) = mpsc::channel::<(RunTicket, Instant)>();
        let rx = std::sync::Mutex::new(rx);
        let mut handles = Vec::new();
        for _ in 0..collectors.max(1) {
            let rx = &rx;
            handles.push(s.spawn(move || {
                let mut lat = Vec::new();
                loop {
                    // Holding the lock across recv is intended: at most
                    // one collector parks on an empty queue; the rest
                    // queue on the mutex and each wakes for the next
                    // ticket as soon as it is free.
                    let msg = rx.lock().expect("collector lock").recv();
                    match msg {
                        Ok((ticket, submitted)) => {
                            ticket.wait().expect("accepted request failed");
                            lat.push(submitted.elapsed());
                        }
                        Err(_) => break,
                    }
                }
                lat
            }));
        }
        let mut rng = XorShift::new(0x0bea);
        for i in 0..arrivals {
            // Fixed arrival schedule, independent of completions — the
            // defining property of an open loop.
            let due = t0 + Duration::from_secs_f64(i as f64 / rate);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            let idx = (rng.next_u64() % names.len() as u64) as usize;
            match pool.try_submit_run(&names[idx], inputs[idx].clone()) {
                Ok(ticket) => {
                    tx.send((ticket, Instant::now()))
                        .expect("collector gone");
                }
                Err(SubmitError::Busy) => shed += 1,
                Err(SubmitError::Engine(e)) => return Err(e.into()),
            }
        }
        drop(tx);
        for h in handles {
            latencies.extend(h.join().expect("collector panicked"));
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    let scratch = pool.stats().scratch;
    pool.shutdown();

    latencies.sort();
    let served = arrivals - shed;
    Ok(Cell {
        mode: "open",
        pool: pool_size,
        clients: collectors,
        threads,
        queue_depth,
        requests: arrivals,
        target_rps: rate,
        shed,
        wall_s: wall,
        rps: served as f64 / wall,
        p50_ms: percentile_ms(&latencies, 0.50),
        p95_ms: percentile_ms(&latencies, 0.95),
        scratch_hits: scratch.hits.saturating_sub(warmed.hits),
        scratch_grows: scratch.grows,
        steady_grows: scratch.grows.saturating_sub(warmed.grows),
        scratch_high_water: scratch.high_water_bytes,
    })
}

/// Drive one closed-loop phase against an already-running pool,
/// restricted to a subset of the zoo.  Returns (wall seconds, sorted
/// per-request latencies).
fn run_phase(
    pool: &EnginePool,
    mix: &[(String, Vec<Vec<f32>>)],
    clients: usize,
    requests_per_client: usize,
    seed: u64,
) -> (f64, Vec<Duration>) {
    let t0 = Instant::now();
    let mut latencies: Vec<Duration> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || {
                    let mut rng = XorShift::new(seed + c as u64);
                    let mut lat = Vec::with_capacity(requests_per_client);
                    for _ in 0..requests_per_client {
                        let i =
                            (rng.next_u64() % mix.len() as u64) as usize;
                        let t = Instant::now();
                        let out =
                            pool.run(&mix[i].0, mix[i].1.clone()).unwrap();
                        lat.push(t.elapsed());
                        assert!(!out.outputs[0].is_empty());
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            latencies.extend(h.join().expect("client thread panicked"));
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort();
    (wall, latencies)
}

/// Gather a named subset of the zoo with synthesized inputs.
fn phase_mix(
    pool: &EnginePool,
    names: &[&str],
) -> Result<Vec<(String, Vec<Vec<f32>>)>, Box<dyn std::error::Error>> {
    let mut mix = Vec::with_capacity(names.len());
    for name in names {
        mix.push((name.to_string(), pool.synth_inputs(name, 17)?));
    }
    Ok(mix)
}

/// The online re-tuning demonstration (`--phase-shift`).
///
/// 1. Seed the pool's tuning DB with a deliberately poisoned selection
///    for the shape class `serve_gemm_96` and `serve_gemm_128` bucket
///    into — the kind of stale entry a DB tuned on different hardware
///    (or different traffic) leaves behind.
/// 2. **steady**: serve a mix that never touches the poisoned class.
/// 3. **shifted**: shift traffic onto the poisoned class; every request
///    now plans from the bad point and throughput craters.
/// 4. Re-tune: rank hot shape classes from the pool's own per-class
///    latency accounting, sweep exactly those classes on a probe
///    engine, and promote only candidates that *measured* strictly
///    faster than the incumbent; broadcast the published epoch into the
///    serving pool ([`EnginePool::swap_tuning`]).
/// 5. **retuned**: the same shifted mix again — throughput recovers.
///
/// Returns the three phase cells plus (steady, retuned) throughput for
/// the CI recovery assertion.
fn run_phase_shift(
    store: &ArtifactStore,
    actors: usize,
    clients: usize,
    requests_per_client: usize,
    queue_depth: usize,
) -> Result<(Vec<Cell>, f64, f64), Box<dyn std::error::Error>> {
    // 8x8x8 tiles, a 2x2 micro-kernel, and 8-way threading is
    // pathological for ~100-element GEMMs: all packing overhead, no
    // register reuse, heavy oversubscription.
    let poison = GemmPoint::scalar(BlockedParams {
        bm: 8,
        bn: 8,
        bk: 8,
        mr: 2,
        nr: 2,
        threads: 8,
    });
    let mut seed_db = SelectionDb::new();
    seed_db.put(SelectionKey::gemm(HOST_DEVICE, 96, 96, 96), poison, 0.01);
    let handle = TuningHandle::new(seed_db);

    let config = PoolConfig {
        actors,
        queue_depth,
        spill_depth: (queue_depth / 2).max(1),
        ..Default::default()
    };
    let pool = EnginePool::native_tuned(
        store.clone(),
        Arc::clone(&handle.snapshot().db),
        config,
    )?;
    for meta_name in store.iter().map(|m| m.name.clone()) {
        pool.warm(&meta_name)?;
    }

    // Steady traffic stays off the poisoned class (160 and 192 bucket
    // into gemm_256x256x256); the shifted mix lands squarely on it.
    let steady_mix = phase_mix(
        &pool,
        &["serve_gemm_160", "serve_conv_16", "serve_conv_24"],
    )?;
    let shifted_mix = phase_mix(&pool, &["serve_gemm_96", "serve_gemm_128"])?;

    let cell = |mode: &'static str,
                wall: f64,
                lat: &[Duration],
                before: ScratchStats,
                after: ScratchStats| Cell {
        mode,
        pool: actors,
        clients,
        threads: 0,
        queue_depth,
        requests: clients * requests_per_client,
        target_rps: 0.0,
        shed: 0,
        wall_s: wall,
        rps: (clients * requests_per_client) as f64 / wall,
        p50_ms: percentile_ms(lat, 0.50),
        p95_ms: percentile_ms(lat, 0.95),
        scratch_hits: after.hits.saturating_sub(before.hits),
        scratch_grows: after.grows,
        steady_grows: after.grows.saturating_sub(before.grows),
        scratch_high_water: after.high_water_bytes,
    };

    let s_warm = pool.stats().scratch;
    let (wall_a, lat_a) =
        run_phase(&pool, &steady_mix, clients, requests_per_client, 0x5eed);
    let s_steady = pool.stats().scratch;
    let steady = cell("steady", wall_a, &lat_a, s_warm, s_steady);
    println!(
        "phase steady : {:>8.1} req/s  p50 {:>7.2} ms  p95 {:>7.2} ms",
        steady.rps, steady.p50_ms, steady.p95_ms
    );

    let (wall_b, lat_b) =
        run_phase(&pool, &shifted_mix, clients, requests_per_client, 0xfade);
    let s_shifted = pool.stats().scratch;
    let shifted = cell("shifted", wall_b, &lat_b, s_steady, s_shifted);
    println!(
        "phase shifted: {:>8.1} req/s  p50 {:>7.2} ms  p95 {:>7.2} ms  \
         (poisoned selection in play)",
        shifted.rps, shifted.p50_ms, shifted.p95_ms
    );

    // The pool's own accounting names the classes worth re-tuning.
    let stats = pool.stats();
    let hot = stats.hot_shape_classes(2);
    println!("hot shape classes by total serving time: {hot:?}");

    // Probe on a fresh engine with no tuning DB attached (a tuned
    // engine would override the probe points at plan time).
    let mut probe = NativeEngine::new(store.clone())?;
    let cfg = RetuneConfig::default();
    let pass = retune_native(&mut probe, &handle, &hot, &cfg)?;
    for p in &pass.promoted {
        println!(
            "promoted {}::{} -> {} ({:.2} -> {:.2} GFLOP/s measured)",
            p.key.device,
            p.key.op,
            p.point,
            p.incumbent_gflops,
            p.candidate_gflops
        );
    }
    println!(
        "re-tune pass: probed {} artifacts, promoted {}, rejected {} \
         (epoch {:?})",
        pass.probed,
        pass.promoted.len(),
        pass.rejected,
        pass.epoch
    );

    let snap = handle.snapshot();
    let applied = pool.swap_tuning(&snap);
    println!(
        "swapped tuning epoch {} into {applied}/{} healthy actors",
        snap.epoch,
        pool.healthy_actors()
    );

    // Re-plan prewarming from the tuning swap lands between here and the
    // retuned phase; baseline after it so the retuned cell's
    // `steady_grows` reads serving-time growth only.
    let s_post_swap = pool.stats().scratch;
    let (wall_c, lat_c) =
        run_phase(&pool, &shifted_mix, clients, requests_per_client, 0xcafe);
    let s_retuned = pool.stats().scratch;
    let retuned = cell("retuned", wall_c, &lat_c, s_post_swap, s_retuned);
    println!(
        "phase retuned: {:>8.1} req/s  p50 {:>7.2} ms  p95 {:>7.2} ms",
        retuned.rps, retuned.p50_ms, retuned.p95_ms
    );

    // Per-(artifact, shape-class) serving latency, the accounting the
    // hot ranking was read from.
    let final_stats = pool.stats();
    println!(
        "arena: {} hits, {} grows, high water {} KiB across {} actors",
        final_stats.scratch.hits,
        final_stats.scratch.grows,
        final_stats.scratch.high_water_bytes / 1024,
        pool.healthy_actors()
    );
    println!(
        "tuning epoch {}  spills {}  per-class serving latency:",
        final_stats.tuning_epoch,
        pool.spilled()
    );
    println!(
        "  {:<38} {:>8} {:>10} {:>10}",
        "artifact::shape_class", "count", "mean_ms", "~p95_ms"
    );
    for (key, lat) in &final_stats.latency {
        println!(
            "  {:<38} {:>8} {:>10.3} {:>10.3}",
            key,
            lat.count,
            lat.mean().as_secs_f64() * 1e3,
            lat.approx_percentile(0.95).as_secs_f64() * 1e3
        );
    }
    pool.shutdown();

    let steady_rps = steady.rps;
    let retuned_rps = retuned.rps;
    Ok((vec![steady, shifted, retuned], steady_rps, retuned_rps))
}

fn parse_pools(spec: &str) -> Result<Vec<usize>, Box<dyn std::error::Error>> {
    let pools: Result<Vec<usize>, _> =
        spec.split(',').map(|s| s.trim().parse::<usize>()).collect();
    let pools = pools.map_err(|_| format!("bad --pools list {spec:?}"))?;
    if pools.is_empty() || pools.contains(&0) {
        return Err(format!("--pools needs positive sizes, got {spec:?}").into());
    }
    Ok(pools)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut pools: Vec<usize> = vec![1, 2];
    let mut clients = 8usize;
    let mut requests = 40usize;
    let mut threads = 1usize;
    let mut queue_depth = 64usize;
    let mut out_dir = PathBuf::from("reports");
    let mut smoke = false;
    let mut assert_speedup: Option<f64> = None;
    let mut open_loop: Option<f64> = None;
    let mut phase_shift = false;
    let mut assert_recovery: Option<f64> = None;

    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        let mut value = |what: &str| {
            it.next().ok_or_else(|| format!("{what} needs a value"))
        };
        match arg.as_str() {
            "--pools" => pools = parse_pools(&value("--pools")?)?,
            "--clients" => clients = value("--clients")?.parse()?,
            "--requests" => requests = value("--requests")?.parse()?,
            "--threads" => threads = value("--threads")?.parse()?,
            "--depth" => queue_depth = value("--depth")?.parse()?,
            "--out" => out_dir = PathBuf::from(value("--out")?),
            "--smoke" => smoke = true,
            "--assert-speedup" => {
                assert_speedup = Some(value("--assert-speedup")?.parse()?)
            }
            "--open-loop" => {
                let rate: f64 = value("--open-loop")?.parse()?;
                if rate <= 0.0 || !rate.is_finite() {
                    return Err("--open-loop needs a positive rate".into());
                }
                open_loop = Some(rate);
            }
            "--phase-shift" => phase_shift = true,
            "--assert-recovery" => {
                let r: f64 = value("--assert-recovery")?.parse()?;
                if r <= 0.0 || !r.is_finite() {
                    return Err("--assert-recovery needs a positive ratio"
                        .into());
                }
                assert_recovery = Some(r);
            }
            other => {
                return Err(format!(
                    "unknown argument {other:?}; usage: serve_loadgen \
                     [--pools 1,2,..] [--clients M] [--requests R] \
                     [--threads T] [--depth D] [--out DIR] [--smoke] \
                     [--assert-speedup X] [--open-loop RATE] \
                     [--phase-shift] [--assert-recovery R]"
                )
                .into())
            }
        }
    }
    if smoke {
        // The CI contract: pool sizes 1 and 2 on one contention
        // workload, serial kernels so pool width is the only
        // parallelism axis.  The contract is closed-loop by definition.
        if open_loop.is_some() {
            return Err("--smoke and --open-loop are exclusive".into());
        }
        pools = vec![1, 2];
        threads = 1;
    }

    let zoo = TempDir::new("serve-loadgen")?;
    write_zoo(zoo.path());
    let store = ArtifactStore::open(zoo.path())?;

    if phase_shift {
        if smoke || open_loop.is_some() {
            return Err(
                "--phase-shift is exclusive with --smoke/--open-loop".into()
            );
        }
        let actors = pools[0].max(2);
        println!(
            "== serve_loadgen (phase shift): {} artifacts, {clients} \
             clients x {requests} requests/phase, pool={actors} ==",
            store.len()
        );
        let (cells, steady_rps, retuned_rps) =
            run_phase_shift(&store, actors, clients, requests, queue_depth)?;

        std::fs::create_dir_all(&out_dir)?;
        let csv_path = out_dir.join("serve_loadgen.csv");
        let mut csv = String::from(Cell::csv_header());
        csv.push('\n');
        for cell in &cells {
            csv.push_str(&cell.csv_row());
            csv.push('\n');
        }
        std::fs::write(&csv_path, csv)?;
        println!("wrote {}", csv_path.display());

        if let Some(required) = assert_recovery {
            let ratio = retuned_rps / steady_rps;
            println!(
                "recovery: retuned / steady throughput = {ratio:.2}x \
                 (required >= {required:.2}x)"
            );
            if ratio < required {
                return Err(format!(
                    "phase-shift recovery failed: post-re-tune throughput \
                     {retuned_rps:.1} req/s is only {ratio:.2}x the \
                     pre-shift steady state {steady_rps:.1} req/s (need >= \
                     {required:.2}x): an online re-tune must restore \
                     serving throughput"
                )
                .into());
            }
            println!(
                "OK: online re-tune restored >= {required:.2}x steady \
                 throughput"
            );
        }
        return Ok(());
    }

    match open_loop {
        Some(rate) => println!(
            "== serve_loadgen (open loop): {} artifacts, {} arrivals at \
             {rate} req/s, threads={threads}, pools {pools:?} ==",
            store.len(),
            clients * requests
        ),
        None => println!(
            "== serve_loadgen: {} artifacts, {clients} clients x \
             {requests} requests, threads={threads}, pools {pools:?} ==",
            store.len()
        ),
    }

    let mut cells: Vec<Cell> = Vec::new();
    for &pool_size in &pools {
        let cell = match open_loop {
            Some(rate) => run_cell_open(
                &store,
                pool_size,
                clients,
                threads,
                queue_depth,
                clients * requests,
                rate,
            )?,
            None => run_cell(
                &store, pool_size, clients, threads, queue_depth, requests,
            )?,
        };
        println!(
            "pool={:<2} threads={threads}: {:>8.1} req/s  p50 {:>7.2} ms  \
             p95 {:>7.2} ms  shed {:>4} ({:>5.1}%)  arena +{} grows  \
             (wall {:.2} s, {} {})",
            cell.pool,
            cell.rps,
            cell.p50_ms,
            cell.p95_ms,
            cell.shed,
            cell.shed_rate() * 100.0,
            cell.steady_grows,
            cell.wall_s,
            cell.requests,
            if cell.mode == "open" { "arrivals" } else { "requests" }
        );
        cells.push(cell);
    }

    std::fs::create_dir_all(&out_dir)?;
    let csv_path = out_dir.join("serve_loadgen.csv");
    let mut csv = String::from(Cell::csv_header());
    csv.push('\n');
    for cell in &cells {
        csv.push_str(&cell.csv_row());
        csv.push('\n');
    }
    std::fs::write(&csv_path, csv)?;
    println!("wrote {}", csv_path.display());

    if smoke {
        let min_speedup = assert_speedup.unwrap_or(1.0);
        let single = cells
            .iter()
            .find(|c| c.pool == 1)
            .ok_or("smoke needs the pool=1 cell")?;
        let pooled = cells
            .iter()
            .find(|c| c.pool == 2)
            .ok_or("smoke needs the pool=2 cell")?;
        let ratio = pooled.rps / single.rps;
        println!(
            "smoke: pool(2) / pool(1) throughput = {ratio:.2}x \
             (required >= {min_speedup:.2}x)"
        );
        if ratio < min_speedup {
            return Err(format!(
                "serving smoke failed: pool(2) at {:.1} req/s is only \
                 {ratio:.2}x pool(1) at {:.1} req/s (need >= \
                 {min_speedup:.2}x): scale-out must not lose throughput \
                 under contention",
                pooled.rps, single.rps
            )
            .into());
        }
        println!("OK: pool(2) sustains >= {min_speedup:.2}x single-actor throughput");

        // The arena contract: after warmup (every plan prewarmed its
        // worst-case workspace), steady-state serving must not grow the
        // arena — kernel hot paths run allocation-free.
        let steady_grows: u64 = cells.iter().map(|c| c.steady_grows).sum();
        if steady_grows != 0 {
            return Err(format!(
                "serving smoke failed: {steady_grows} arena growth \
                 allocation(s) during steady-state serving: plan-time \
                 workspace sizing must make warmed kernel hot paths \
                 allocation-free"
            )
            .into());
        }
        println!(
            "OK: zero arena growth after warmup across {} cells \
             (allocation-free steady state)",
            cells.len()
        );
    }
    Ok(())
}
