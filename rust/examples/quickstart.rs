//! Quickstart: load one AOT-compiled parametrized GEMM kernel and run it.
//!
//! ```sh
//! make artifacts            # once: python lowers kernels to HLO text
//! cargo run --release --example quickstart
//! ```
//!
//! This is the whole request-path story in one page: the artifact was
//! produced at build time by the Pallas GEMM instantiated with the paper's
//! `4x4_8x8_loc` configuration; Rust loads the manifest, plans/compiles it
//! once on the default backend (the pure-Rust native engine offline, PJRT
//! under `--features pjrt`), executes it, and verifies the numbers against
//! the naive GEMM oracle.

use portable_kernels::blas::{gemm_naive, max_abs_diff};
use portable_kernels::runtime::{ArtifactStore, Backend, DefaultEngine};
use portable_kernels::util::rng::XorShift;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::path::Path::new("artifacts");
    let store = ArtifactStore::open(dir)?;
    let mut engine = DefaultEngine::new(store)?;
    println!("backend: {}", engine.platform());

    // The quickstart artifact is a 64x64x64 GEMM with the paper's
    // 4x4_8x8_loc configuration (see python/compile/manifests.py).
    let name = "quickstart_gemm";
    let meta = engine.store().get(name)?.clone();
    println!(
        "artifact {name}: config {:?}, {} flops",
        meta.config, meta.flops
    );

    let (m, n, k) = (
        meta.m.unwrap() as usize,
        meta.n.unwrap() as usize,
        meta.k.unwrap() as usize,
    );
    let mut rng = XorShift::new(7);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);

    let out = engine.run(name, &[a.clone(), b.clone()])?;
    println!(
        "executed in {:?} -> {:.2} GFLOP/s",
        out.elapsed,
        out.gflops(meta.flops)
    );

    // Verify against the host-Rust oracle.
    let expected = gemm_naive(&a, &b, m, n, k);
    let err = max_abs_diff(&out.outputs[0], &expected);
    println!("max |backend - rust_naive| = {err:.2e}");
    if err >= 1e-3 {
        return Err(format!("numerics mismatch: {err:.2e}").into());
    }
    println!("quickstart OK");
    Ok(())
}
