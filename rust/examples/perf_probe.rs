//! Perf probe: where does request time go? (literal build vs execute vs
//! readback).  PJRT-only — build with `--features pjrt`.
use portable_kernels::runtime::{ArtifactStore, Backend, Engine};
use std::time::Instant;
fn main() {
    let dir = std::path::Path::new("artifacts");
    let mut engine = Engine::new(ArtifactStore::open(dir).unwrap()).unwrap();
    for name in ["quickstart_gemm", "gemm_256x256x256_8x4_8x16_loc", "gemm_256x256x256_xla", "net_resnet_conv5_2_xla"] {
        let meta = engine.store().get(name).unwrap().clone();
        let inputs = engine.synth_inputs(name, 3).unwrap();
        engine.warm(name).unwrap();
        // total run (incl literal build) vs engine-reported execute time
        let mut tot = f64::MAX; let mut exe = f64::MAX;
        for _ in 0..5 {
            let t0 = Instant::now();
            let out = engine.run(name, &inputs).unwrap();
            tot = tot.min(t0.elapsed().as_secs_f64());
            exe = exe.min(out.elapsed.as_secs_f64());
        }
        // literal build alone
        let t0 = Instant::now();
        for _ in 0..10 {
            for (d, s) in inputs.iter().zip(&meta.inputs) {
                let _ = xla::Literal::vec1(d).reshape(&s.shape).unwrap();
            }
        }
        let lit = t0.elapsed().as_secs_f64() / 10.0;
        println!("{name}: total {:.3}ms exec {:.3}ms literal-build {:.3}ms overhead {:.3}ms",
                 tot*1e3, exe*1e3, lit*1e3, (tot-exe-lit)*1e3);
    }
}
