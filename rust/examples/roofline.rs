//! Emit the full roofline sweep (paper Figs. 4 & 5) for any modeled
//! device, as CSV on stdout.
//!
//! ```sh
//! cargo run --release --example roofline -- mali-g71 > mali.csv
//! cargo run --release --example roofline                  # defaults to uhd630
//! ```

use portable_kernels::config::GemmConfig;
use portable_kernels::device::device_by_name;
use portable_kernels::harness::sweep::{gemm_sweep, winners_per_point};
use portable_kernels::perfmodel::{vendor_gemm, GemmProblem, VendorLib};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dev_id = std::env::args().nth(1).unwrap_or_else(|| "uhd630".into());
    let dev = device_by_name(&dev_id)?;
    eprintln!("device: {dev}");

    println!("m,n,k,intensity,config,gflops,vendor_gflops,feasible");
    for cfg in GemmConfig::table2() {
        for p in gemm_sweep(&dev, &cfg) {
            let v = vendor_gemm(
                &dev,
                VendorLib::ClBlast,
                GemmProblem::new(p.m, p.n, p.k),
            );
            println!(
                "{},{},{},{:.3},{},{:.2},{:.2},{}",
                p.m, p.n, p.k, p.intensity, p.config, p.gflops, v, p.feasible
            );
        }
    }

    eprintln!("\nper-size winners (fig 5b-d structure):");
    for (m, n, k, name, g) in winners_per_point(&dev, &GemmConfig::table2())
    {
        eprintln!("{m:>5} {n:>5} {k:>5}  {name:<16} {g:>8.2} GF");
    }
    Ok(())
}
