//! End-to-end driver: serve both networks' convolution stacks through the
//! coordinator (real backend execution, batched requests) and report
//! per-layer gigaflops and end-to-end latency — the measured counterpart
//! of the paper's Figs. 6-9, recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example network_inference
//! ```
//!
//! Exercises every layer of the stack: manifest parsing, backend
//! planning/compilation, the engine actor, the batcher, and the network
//! runner.

use std::time::Instant;

use portable_kernels::coordinator::{
    available_layers, BatchPolicy, Batcher, EngineHandle, NetworkRunner,
};
use portable_kernels::harness::Report;
use portable_kernels::runtime::ArtifactStore;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::path::Path::new("artifacts");
    let store = ArtifactStore::open(dir)?;
    let (handle, join) = EngineHandle::spawn(dir)?;
    let runner = NetworkRunner::new(handle.clone());

    // ---- per-layer sweeps: vendor baseline + pallas where available ----
    for net in ["vgg", "resnet"] {
        for implementation in ["xla", "pallas"] {
            let layers = available_layers(&store, net, implementation);
            if layers.is_empty() {
                continue;
            }
            let report =
                runner.run_network(&store, net, implementation, 3)?;
            let mut table = Report::new(
                &format!("{net} / {implementation} (measured)"),
                &["layer", "GFLOP", "ms", "GF/s"],
            );
            for l in &report.layers {
                table.row(vec![
                    l.layer.clone(),
                    format!("{:.3}", l.flops as f64 / 1e9),
                    format!("{:.2}", l.elapsed_s * 1e3),
                    format!("{:.2}", l.gflops),
                ]);
            }
            table.note(format!(
                "total {:.1} ms, {:.2} GFLOP/s",
                report.total_time_s * 1e3,
                report.total_gflops()
            ));
            println!("{}", table.render());
        }
    }

    // ---- batched serving: queue mixed requests, flush in groups ----
    println!("== batched serving demo ==");
    let mut batcher: Batcher<u64> = Batcher::new(BatchPolicy::default());
    // A bursty client: interleaved requests against two ResNet layers.
    let arts =
        ["net_resnet_conv5_2_xla", "net_resnet_conv4_2_xla"];
    for i in 0..24u64 {
        batcher.push(arts[(i % 3 == 2) as usize], i);
    }
    for a in arts {
        handle.warm(a)?;
    }
    let t0 = Instant::now();
    let mut served = 0usize;
    let mut groups = 0usize;
    while let Some((artifact, payloads)) = batcher.pop_group() {
        let inputs = handle.synth_inputs(&artifact, 11)?;
        for _ in &payloads {
            let out = handle.run(&artifact, inputs.clone())?;
            if out.outputs[0].is_empty() {
                return Err("empty output from engine".into());
            }
            served += 1;
        }
        groups += 1;
    }
    let elapsed = t0.elapsed();
    let stats = handle.stats()?;
    println!(
        "served {served} requests in {groups} groups in {:.1} ms \
         ({:.2} ms/request; engine ran {} executions, {} cached executables)",
        elapsed.as_secs_f64() * 1e3,
        elapsed.as_secs_f64() * 1e3 / served as f64,
        stats.runs,
        stats.cached_executables,
    );

    handle.shutdown();
    let _ = join.join();
    println!("network_inference OK");
    Ok(())
}
