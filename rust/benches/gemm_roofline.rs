//! Bench: paper Figures 4a-c and 5a-d — the GEMM roofline sweeps.
//!
//! Two parts:
//! 1. **Modeled** sweeps for the paper's devices (Intel UHD 630, Mali
//!    G-71) — regenerated instantly from the analytic model, CSV to
//!    `reports/`.
//! 2. **Measured** anchors on the host: the Table-2 Pallas GEMM artifacts
//!    vs the XLA-native vendor baseline, executed through PJRT.
//!
//! Run: `cargo bench --bench gemm_roofline` (artifacts required for the
//! measured part; it degrades gracefully without them).

use std::path::Path;

use portable_kernels::blas::gemm_blocked;
use portable_kernels::config::GemmConfig;
use portable_kernels::device::device_by_name;
use portable_kernels::harness::{fig_gemm, Report};
use portable_kernels::runtime::{ArtifactStore, Backend, DefaultEngine};
use portable_kernels::tuner::blocked_grid;
use portable_kernels::util::bench::{bench, black_box};
use portable_kernels::util::rng::XorShift;

fn modeled() {
    let reports_dir = Path::new("reports");
    for (name, report) in [
        ("fig4a", fig_gemm::fig4a()),
        ("fig4b", fig_gemm::fig4b()),
        ("fig4c", fig_gemm::fig4c()),
        ("fig5a", fig_gemm::fig5a()),
        ("fig5_regions", fig_gemm::fig5_regions()),
    ] {
        report
            .save_csv(&reports_dir.join(format!("{name}.csv")))
            .expect("write csv");
        println!("modeled {name}: {} rows -> reports/{name}.csv", report.rows.len());
        for note in &report.notes {
            println!("  note: {note}");
        }
    }
    // Print the condensed fig4a comparison at the largest size.
    let dev = device_by_name("uhd630").unwrap();
    println!("\nfig4a @1024^3 (modeled GF on {}):", dev.id);
    for cfg in GemmConfig::table2() {
        use portable_kernels::perfmodel::{gemm_estimate, GemmProblem};
        let p = GemmProblem::new(1024, 1024, 1024);
        match gemm_estimate(&dev, p, &cfg) {
            Ok(e) => println!("  {:<16} {:>8.1}", cfg.name(), e.gflops),
            Err(_) => println!("  {:<16} infeasible", cfg.name()),
        }
    }
}

fn measured() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("measured part skipped: run `make artifacts`");
        return;
    }
    let store = ArtifactStore::open(dir).unwrap();
    let mut engine = DefaultEngine::new(store).unwrap();

    let mut table = Report::new(
        "measured GEMM anchors (default backend, best of 5)",
        &["artifact", "config", "ms", "GF/s"],
    );
    let names: Vec<String> = engine
        .store()
        .in_group("gemm")
        .map(|m| m.name.clone())
        .collect();
    for name in names {
        let meta = engine.store().get(&name).unwrap().clone();
        let inputs = engine.synth_inputs(&name, 13).unwrap();
        engine.warm(&name).unwrap();
        let stats = bench(&name, 1, 3, || {
            engine.run(&name, &inputs).unwrap();
        });
        table.row(vec![
            meta.name.clone(),
            meta.config.clone().unwrap_or_else(|| "xla".into()),
            format!("{:.3}", stats.min.as_secs_f64() * 1e3),
            format!("{:.2}", stats.gflops(meta.flops)),
        ]);
    }
    println!("\n{}", table.render());
    table
        .save_csv(Path::new("reports/gemm_measured.csv"))
        .expect("write csv");
}

/// Measured host anchor for the paper's sweep story, no artifacts
/// needed: the blocked GEMM kernel across the tuner's
/// `BlockedParams × threads` grid — the same grid `tune_device --quick`
/// sweeps, so bench output and CI tuning DB are directly comparable.
fn host_blocked() {
    let n = 256usize;
    let flops = 2 * (n as u64).pow(3);
    let mut rng = XorShift::new(7);
    let a = rng.f32_vec(n * n);
    let b = rng.f32_vec(n * n);

    let mut table = Report::new(
        &format!("host blocked GEMM {n}^3 across the tuner grid (best of 3)"),
        &["config", "ms", "GF/s"],
    );
    for params in blocked_grid(true, &[1, 2, 0]) {
        let stats = bench(&params.name(), 1, 3, || {
            black_box(gemm_blocked(&a, &b, n, n, n, &params));
        });
        table.row(vec![
            params.name(),
            format!("{:.3}", stats.min.as_secs_f64() * 1e3),
            format!("{:.2}", stats.gflops(flops)),
        ]);
    }
    println!("\n{}", table.render());
    table
        .save_csv(Path::new("reports/gemm_host_sweep.csv"))
        .expect("write csv");
}

fn main() {
    modeled();
    host_blocked();
    measured();
}
