//! Bench: the host-Rust GEMM baselines (naive vs blocked) — the "native
//! library" comparator and a sanity check that blocking pays on the host
//! exactly as §3.1.1 predicts — plus the int8 × ISA section comparing
//! the widening i8 kernels against their f32 twins (GOP/s, CSV to
//! `reports/gemm_int8_host.csv`) and the pack × ISA section comparing
//! A-only against A+B panel packing (CSV to
//! `reports/gemm_pack_host.csv`).
//!
//! Run: `cargo bench --bench rust_blas`.

use portable_kernels::blas::{
    gemm_blocked, gemm_blocked_ex, gemm_blocked_isa, gemm_i8_blocked_isa,
    gemm_naive, gemm_workspace, quantize_slice, BlockedParams, Isa, Pack,
    QuantParams,
};
use portable_kernels::config::micro_kernel_shapes;
use portable_kernels::util::bench::{bench, black_box};
use portable_kernels::util::rng::XorShift;
use portable_kernels::util::scratch::Scratch;

/// The runtime-detected ISA axis end to end: one registry blocking,
/// every micro-kernel variant this host supports — the per-host payoff
/// the tuner's `gemm_point_grid` sweeps measure.
fn isa_sweep() {
    let n = 256usize;
    let mut rng = XorShift::new(0x15a);
    let a = rng.f32_vec(n * n);
    let b = rng.f32_vec(n * n);
    let flops = 2 * (n as u64).pow(3);
    let params =
        BlockedParams { bm: 64, bn: 64, bk: 64, mr: 8, nr: 16, threads: 1 };
    println!(
        "== micro-kernel ISA sweep ({n}^3, serial, {}; detected {:?}) ==",
        params.name(),
        Isa::detect()
    );
    for isa in Isa::detect() {
        let s = bench(&format!("isa {n}^3 {isa}"), 1, 3, || {
            black_box(gemm_blocked_isa(&a, &b, n, n, n, &params, isa));
        });
        println!("{}", s.line(Some(flops)));
    }
    println!();
}

/// The macro-generated micro-kernel registry end to end: one
/// representative blocking, every monomorphized `(mr, nr)` shape — the
/// widened register-tile axis the tuner now sweeps.
fn registry_sweep() {
    let n = 256usize;
    let mut rng = XorShift::new(0x5e6);
    let a = rng.f32_vec(n * n);
    let b = rng.f32_vec(n * n);
    let flops = 2 * (n as u64).pow(3);
    println!("== micro-kernel registry sweep ({n}^3, serial) ==");
    for &(mr, nr) in micro_kernel_shapes() {
        let params = BlockedParams {
            bm: 64,
            bn: 64,
            bk: 64,
            mr,
            nr,
            threads: 1,
        };
        let s = bench(
            &format!("registry {n}^3 {}", params.name()),
            1,
            3,
            || {
                black_box(gemm_blocked(&a, &b, n, n, n, &params));
            },
        );
        println!("{}", s.line(Some(flops)));
    }
    println!();
}

/// The int8 × ISA section: the widening i8×i8→i32 kernel against its
/// f32 twin, per detected ISA, at two sizes.  Integer rows report GOP/s
/// (same useful multiply-add count, honest unit); the per-row CSV lands
/// in `reports/gemm_int8_host.csv` so the speedup is diffable across
/// hosts.  The i8 rows time the raw widening GEMM (quantization done
/// once outside the loop) — the kernel-level counterpart of
/// `tune_device`'s end-to-end head-to-head.
fn int8_isa_sweep() {
    let params =
        BlockedParams { bm: 64, bn: 64, bk: 64, mr: 8, nr: 16, threads: 1 };
    let mut csv = String::from("n,isa,dtype,unit,gops,min_s\n");
    println!(
        "== int8 x ISA sweep (serial, {}; detected {:?}) ==",
        params.name(),
        Isa::detect()
    );
    for &n in &[256usize, 512] {
        let mut rng = XorShift::new(0x18 + n as u64);
        let a = rng.f32_vec(n * n);
        let b = rng.f32_vec(n * n);
        let q = QuantParams { scale: 1.0 / 256.0, zero_point: 0 };
        let aq = quantize_slice(&a, &q);
        let bq = quantize_slice(&b, &q);
        let ops = 2 * (n as u64).pow(3);
        for isa in Isa::detect() {
            let sf = bench(&format!("f32 {n}^3 {isa}"), 1, 3, || {
                black_box(gemm_blocked_isa(&a, &b, n, n, n, &params, isa));
            });
            println!("{}", sf.line(Some(ops)));
            csv.push_str(&format!(
                "{n},{isa},f32,GFLOP/s,{:.3},{:.6}\n",
                sf.gflops(ops),
                sf.min.as_secs_f64()
            ));
            let si = bench(&format!("i8  {n}^3 {isa}"), 1, 3, || {
                black_box(gemm_i8_blocked_isa(
                    &aq, &bq, n, n, n, &params, isa,
                ));
            });
            println!("{}", si.line_int(Some(ops)));
            csv.push_str(&format!(
                "{n},{isa},i8,GOP/s,{:.3},{:.6}\n",
                si.gops(ops),
                si.min.as_secs_f64()
            ));
        }
    }
    if std::fs::create_dir_all("reports").is_ok() {
        let path = "reports/gemm_int8_host.csv";
        match std::fs::write(path, &csv) {
            Ok(()) => println!("int8 csv -> {path}"),
            Err(e) => println!("int8 csv not written ({e})"),
        }
    }
    println!();
}

/// The pack × ISA section: A-only packing against A+B panel packing
/// through the same `gemm_blocked_ex` entry point, per detected ISA, at
/// two sizes.  Scratch comes from a prewarmed arena (the serving shape),
/// so the timed region is allocation-free for both variants and the
/// delta is purely the B-panel layout: streaming `nr`-interleaved panels
/// vs strided loads from the unpacked B.  Per-row CSV lands in
/// `reports/gemm_pack_host.csv`.
fn pack_isa_sweep() {
    let params =
        BlockedParams { bm: 64, bn: 64, bk: 64, mr: 8, nr: 16, threads: 1 };
    let mut csv = String::from("n,isa,pack,gflops,min_s\n");
    println!(
        "== pack x ISA sweep (serial, {}; detected {:?}) ==",
        params.name(),
        Isa::detect()
    );
    let scratch = Scratch::new();
    for &n in &[256usize, 512] {
        let mut rng = XorShift::new(0xb9 + n as u64);
        let a = rng.f32_vec(n * n);
        let b = rng.f32_vec(n * n);
        let flops = 2 * (n as u64).pow(3);
        for isa in Isa::detect() {
            for pack in Pack::all() {
                scratch.prewarm(&gemm_workspace(n, n, n, &params, pack));
                let s = bench(
                    &format!("pack {n}^3 {isa} {pack}"),
                    1,
                    3,
                    || {
                        black_box(gemm_blocked_ex(
                            &a, &b, n, n, n, &params, isa, pack, &scratch,
                        ));
                    },
                );
                println!("{}", s.line(Some(flops)));
                csv.push_str(&format!(
                    "{n},{isa},{pack},{:.3},{:.6}\n",
                    s.gflops(flops),
                    s.min.as_secs_f64()
                ));
            }
        }
    }
    if std::fs::create_dir_all("reports").is_ok() {
        let path = "reports/gemm_pack_host.csv";
        match std::fs::write(path, &csv) {
            Ok(()) => println!("pack csv -> {path}"),
            Err(e) => println!("pack csv not written ({e})"),
        }
    }
    println!();
}

fn main() {
    for &n in &[64usize, 128, 256, 512] {
        let mut rng = XorShift::new(n as u64);
        let a = rng.f32_vec(n * n);
        let b = rng.f32_vec(n * n);
        let flops = 2 * (n as u64).pow(3);

        let s = bench(&format!("naive {n}^3"), 1, 5, || {
            black_box(gemm_naive(&a, &b, n, n, n));
        });
        println!("{}", s.line(Some(flops)));

        // Serial configs plus the same shapes threaded: the `threads`
        // knob is one more parameter of the sweep, not a separate mode.
        for params in [
            BlockedParams { bm: 32, bn: 32, bk: 32, mr: 4, nr: 8, threads: 1 },
            BlockedParams { threads: 1, ..Default::default() },
            BlockedParams {
                bm: 128, bn: 128, bk: 64, mr: 8, nr: 16, threads: 1,
            },
            BlockedParams { threads: 2, ..Default::default() },
            BlockedParams::default(), // threads: 0 = all cores
        ] {
            let s = bench(
                &format!("blocked {n}^3 {}", params.name()),
                1,
                5,
                || {
                    black_box(gemm_blocked(&a, &b, n, n, n, &params));
                },
            );
            println!("{}", s.line(Some(flops)));
        }
        println!();
    }
    registry_sweep();
    isa_sweep();
    int8_isa_sweep();
    pack_isa_sweep();
}
