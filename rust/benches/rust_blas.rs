//! Bench: the host-Rust GEMM baselines (naive vs blocked) — the "native
//! library" comparator and a sanity check that blocking pays on the host
//! exactly as §3.1.1 predicts.
//!
//! Run: `cargo bench --bench rust_blas`.

use portable_kernels::blas::{gemm_blocked, gemm_naive, BlockedParams};
use portable_kernels::util::bench::{bench, black_box};
use portable_kernels::util::rng::XorShift;

fn main() {
    for &n in &[64usize, 128, 256, 512] {
        let mut rng = XorShift::new(n as u64);
        let a = rng.f32_vec(n * n);
        let b = rng.f32_vec(n * n);
        let flops = 2 * (n as u64).pow(3);

        let s = bench(&format!("naive {n}^3"), 1, 5, || {
            black_box(gemm_naive(&a, &b, n, n, n));
        });
        println!("{}", s.line(Some(flops)));

        for params in [
            BlockedParams { bm: 32, bn: 32, bk: 32, mr: 4, nr: 8 },
            BlockedParams::default(),
            BlockedParams { bm: 128, bn: 128, bk: 64, mr: 8, nr: 16 },
        ] {
            let s = bench(
                &format!(
                    "blocked {n}^3 bm{} bn{} bk{} {}x{}",
                    params.bm, params.bn, params.bk, params.mr, params.nr
                ),
                1,
                5,
                || {
                    black_box(gemm_blocked(&a, &b, n, n, n, &params));
                },
            );
            println!("{}", s.line(Some(flops)));
        }
        println!();
    }
}
