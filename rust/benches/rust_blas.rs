//! Bench: the host-Rust GEMM baselines (naive vs blocked) — the "native
//! library" comparator and a sanity check that blocking pays on the host
//! exactly as §3.1.1 predicts.
//!
//! Run: `cargo bench --bench rust_blas`.

use portable_kernels::blas::{
    gemm_blocked, gemm_blocked_isa, gemm_naive, BlockedParams, Isa,
};
use portable_kernels::config::micro_kernel_shapes;
use portable_kernels::util::bench::{bench, black_box};
use portable_kernels::util::rng::XorShift;

/// The runtime-detected ISA axis end to end: one registry blocking,
/// every micro-kernel variant this host supports — the per-host payoff
/// the tuner's `gemm_point_grid` sweeps measure.
fn isa_sweep() {
    let n = 256usize;
    let mut rng = XorShift::new(0x15a);
    let a = rng.f32_vec(n * n);
    let b = rng.f32_vec(n * n);
    let flops = 2 * (n as u64).pow(3);
    let params =
        BlockedParams { bm: 64, bn: 64, bk: 64, mr: 8, nr: 16, threads: 1 };
    println!(
        "== micro-kernel ISA sweep ({n}^3, serial, {}; detected {:?}) ==",
        params.name(),
        Isa::detect()
    );
    for isa in Isa::detect() {
        let s = bench(&format!("isa {n}^3 {isa}"), 1, 3, || {
            black_box(gemm_blocked_isa(&a, &b, n, n, n, &params, isa));
        });
        println!("{}", s.line(Some(flops)));
    }
    println!();
}

/// The macro-generated micro-kernel registry end to end: one
/// representative blocking, every monomorphized `(mr, nr)` shape — the
/// widened register-tile axis the tuner now sweeps.
fn registry_sweep() {
    let n = 256usize;
    let mut rng = XorShift::new(0x5e6);
    let a = rng.f32_vec(n * n);
    let b = rng.f32_vec(n * n);
    let flops = 2 * (n as u64).pow(3);
    println!("== micro-kernel registry sweep ({n}^3, serial) ==");
    for &(mr, nr) in micro_kernel_shapes() {
        let params = BlockedParams {
            bm: 64,
            bn: 64,
            bk: 64,
            mr,
            nr,
            threads: 1,
        };
        let s = bench(
            &format!("registry {n}^3 {}", params.name()),
            1,
            3,
            || {
                black_box(gemm_blocked(&a, &b, n, n, n, &params));
            },
        );
        println!("{}", s.line(Some(flops)));
    }
    println!();
}

fn main() {
    for &n in &[64usize, 128, 256, 512] {
        let mut rng = XorShift::new(n as u64);
        let a = rng.f32_vec(n * n);
        let b = rng.f32_vec(n * n);
        let flops = 2 * (n as u64).pow(3);

        let s = bench(&format!("naive {n}^3"), 1, 5, || {
            black_box(gemm_naive(&a, &b, n, n, n));
        });
        println!("{}", s.line(Some(flops)));

        // Serial configs plus the same shapes threaded: the `threads`
        // knob is one more parameter of the sweep, not a separate mode.
        for params in [
            BlockedParams { bm: 32, bn: 32, bk: 32, mr: 4, nr: 8, threads: 1 },
            BlockedParams { threads: 1, ..Default::default() },
            BlockedParams {
                bm: 128, bn: 128, bk: 64, mr: 8, nr: 16, threads: 1,
            },
            BlockedParams { threads: 2, ..Default::default() },
            BlockedParams::default(), // threads: 0 = all cores
        ] {
            let s = bench(
                &format!("blocked {n}^3 {}", params.name()),
                1,
                5,
                || {
                    black_box(gemm_blocked(&a, &b, n, n, n, &params));
                },
            );
            println!("{}", s.line(Some(flops)));
        }
        println!();
    }
    registry_sweep();
    isa_sweep();
}
