//! Bench: paper Figures 6-9 — per-layer network gigaflops.
//!
//! Modeled on the paper's two testbeds (HiKey 960, i7-6700K), measured on
//! the host through the coordinator's network runner.
//!
//! Run: `cargo bench --bench network_layers`.

use std::path::Path;

use portable_kernels::coordinator::{
    available_layers, EngineHandle, NetworkRunner,
};
use portable_kernels::harness::{fig_network, Report};
use portable_kernels::runtime::ArtifactStore;

fn modeled() {
    let reports = Path::new("reports");
    for (fid, net, bed) in [
        ("fig6", "resnet", "hikey960"),
        ("fig7", "resnet", "i7-6700k"),
        ("fig8", "vgg", "hikey960"),
        ("fig9", "vgg", "i7-6700k"),
    ] {
        let r = fig_network::fig_network(net, bed).unwrap();
        r.save_csv(&reports.join(format!("{fid}.csv"))).unwrap();
        println!("modeled {fid}: {} layers -> reports/{fid}.csv", r.rows.len());
        for note in &r.notes {
            println!("  note: {note}");
        }
    }
}

fn measured() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("measured part skipped: run `make artifacts`");
        return;
    }
    let store = ArtifactStore::open(dir).unwrap();
    let (handle, join) = EngineHandle::spawn(dir).unwrap();
    let runner = NetworkRunner::new(handle.clone());

    for net in ["resnet", "vgg"] {
        for implementation in ["xla", "pallas"] {
            if available_layers(&store, net, implementation).is_empty() {
                continue;
            }
            let rep = runner
                .run_network(&store, net, implementation, 3)
                .unwrap();
            let mut table = Report::new(
                &format!("measured {net}/{implementation} per-layer (PJRT CPU)"),
                &["layer", "ms", "GF/s"],
            );
            for l in &rep.layers {
                table.row(vec![
                    l.layer.clone(),
                    format!("{:.2}", l.elapsed_s * 1e3),
                    format!("{:.2}", l.gflops),
                ]);
            }
            table.note(format!(
                "total {:.1} ms, {:.2} GF/s",
                rep.total_time_s * 1e3,
                rep.total_gflops()
            ));
            println!("{}", table.render());
            table
                .save_csv(Path::new(&format!(
                    "reports/network_{net}_{implementation}_measured.csv"
                )))
                .unwrap();
        }
    }
    handle.shutdown();
    let _ = join.join();
}

fn main() {
    modeled();
    measured();
}
