//! Bench: the analytic model + tuner themselves (the coordinator-side hot
//! path: a full exhaustive tune must be cheap enough to run at startup).
//!
//! Run: `cargo bench --bench perfmodel`.

use portable_kernels::config::GemmConfig;
use portable_kernels::device::device_by_name;
use portable_kernels::nn::ConvLayer;
use portable_kernels::perfmodel::{gemm_estimate, GemmProblem};
use portable_kernels::tuner::{tune_conv, tune_gemm, ExhaustiveSearch, HillClimb};
use portable_kernels::util::bench::{bench, black_box};

fn main() {
    let dev = device_by_name("mali-g71").unwrap();
    let p = GemmProblem::new(512, 512, 512);
    let cfg = GemmConfig::parse("8x4_8x16_loc").unwrap();

    let s = bench("gemm_estimate (single)", 100, 1000, || {
        black_box(gemm_estimate(&dev, p, &cfg).unwrap());
    });
    println!("{}", s.line(None));

    let s = bench("tune_gemm exhaustive (432 configs)", 2, 30, || {
        black_box(tune_gemm(&dev, p, &ExhaustiveSearch).unwrap());
    });
    println!("{}", s.line(None));

    let s = bench("tune_gemm hillclimb", 2, 30, || {
        black_box(
            tune_gemm(&dev, p, &HillClimb { restarts: 8, seed: 42 }).unwrap(),
        );
    });
    println!("{}", s.line(None));

    let layer = ConvLayer::same("bench", 3, 1, 56, 56, 128, 256);
    let s = bench("tune_conv exhaustive (incl. nested gemm tune)", 1, 10, || {
        black_box(tune_conv(&dev, &layer, 1, &ExhaustiveSearch).unwrap());
    });
    println!("{}", s.line(None));

    // Whole-network tuning cost (the startup path of the coordinator).
    let s = bench("tune all 26 resnet layers", 1, 3, || {
        for l in portable_kernels::nn::resnet50_layers() {
            black_box(tune_conv(&dev, &l, 1, &ExhaustiveSearch).unwrap());
        }
    });
    println!("{}", s.line(None));
}
