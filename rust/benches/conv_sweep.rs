//! Bench: paper Figures 2 & 3 — conv register usage and the tile/vector
//! throughput sweep — plus measured conv-algorithm anchors on the host.
//!
//! Run: `cargo bench --bench conv_sweep`.

use std::path::Path;

use portable_kernels::blas::{
    conv2d_im2col, conv2d_native_isa, conv2d_winograd, BlockedParams,
    Conv2dShape, Isa,
};
use portable_kernels::config::ConvAlgorithm;
use portable_kernels::harness::{fig_conv, fig_registers, Report};
use portable_kernels::runtime::{ArtifactStore, Backend, DefaultEngine};
use portable_kernels::tuner::{blocked_grid, conv_native_grid};
use portable_kernels::util::bench::{bench, black_box};
use portable_kernels::util::rng::XorShift;

fn modeled() {
    let reports = Path::new("reports");
    let f2 = fig_registers::fig2();
    f2.save_csv(&reports.join("fig2.csv")).unwrap();
    println!("modeled fig2: {} rows -> reports/fig2.csv", f2.rows.len());

    let f3 = fig_conv::fig3();
    f3.save_csv(&reports.join("fig3.csv")).unwrap();
    println!("modeled fig3: {} rows -> reports/fig3.csv", f3.rows.len());
    for note in &f3.notes {
        println!("  note: {note}");
    }
}

/// Measured: the same layer through naive/tiled/im2col/winograd Pallas
/// kernels and the XLA vendor baseline — the host anchor for Fig. 3's
/// "algorithm and tile choice matter" story.
fn measured() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("measured part skipped: run `make artifacts`");
        return;
    }
    let store = ArtifactStore::open(dir).unwrap();
    let mut engine = DefaultEngine::new(store).unwrap();

    let mut table = Report::new(
        "measured conv algorithms (default backend, best of 3)",
        &["artifact", "algorithm", "ms", "effective GF/s", "scaled"],
    );
    let names: Vec<String> = engine
        .store()
        .in_group("conv")
        .map(|m| m.name.clone())
        .collect();
    for name in names {
        let meta = engine.store().get(&name).unwrap().clone();
        let inputs = engine.synth_inputs(&name, 29).unwrap();
        engine.warm(&name).unwrap();
        let stats = bench(&name, 1, 2, || {
            engine.run(&name, &inputs).unwrap();
        });
        table.row(vec![
            meta.name.clone(),
            meta.algorithm.clone().unwrap_or_default(),
            format!("{:.3}", stats.min.as_secs_f64() * 1e3),
            format!("{:.2}", stats.gflops(meta.flops)),
            meta.scaled_from.clone().unwrap_or_default(),
        ]);
    }
    println!("\n{}", table.render());
    table
        .save_csv(Path::new("reports/conv_measured.csv"))
        .expect("write csv");
}

/// Measured host anchor, no artifacts needed: the im2col conv kernel on
/// a conv3_1-ish layer across the tuner's `BlockedParams × threads`
/// grid — the host counterpart of Fig. 3's "tile and vector choice
/// matter" sweep.
fn host_blocked() {
    let s = Conv2dShape::same(2, 32, 32, 16, 32, 3, 1);
    let flops = 2 * (s.batch * s.out_h * s.out_w * s.out_c
        * s.window * s.window * s.in_c) as u64;
    let mut rng = XorShift::new(11);
    let x = rng.f32_vec(s.input_elems());
    let f = rng.f32_vec(s.filter_elems());

    let mut table = Report::new(
        "host im2col conv 2x32x32x16->32 across the tuner grid (best of 3)",
        &["config", "ms", "effective GF/s"],
    );
    for params in blocked_grid(true, &[1, 2, 0]) {
        let stats = bench(&params.name(), 1, 3, || {
            black_box(conv2d_im2col(&x, &f, &s, &params));
        });
        table.row(vec![
            params.name(),
            format!("{:.3}", stats.min.as_secs_f64() * 1e3),
            format!("{:.2}", stats.gflops(flops)),
        ]);
    }
    println!("\n{}", table.render());
    table
        .save_csv(Path::new("reports/conv_host_sweep.csv"))
        .expect("write csv");
}

/// Measured host anchor for the *algorithm* axis: the same 3×3/s1 layer
/// through every native algorithm × config × threads × ISA candidate of
/// the tuner's conv grid — Fig. 3's "the winning algorithm flips" story,
/// measured on the host with no artifacts needed.
fn host_algorithms() {
    let s = Conv2dShape::same(2, 32, 32, 16, 32, 3, 1);
    let flops = 2 * (s.batch * s.out_h * s.out_w * s.out_c
        * s.window * s.window * s.in_c) as u64;
    let mut rng = XorShift::new(13);
    let x = rng.f32_vec(s.input_elems());
    let f = rng.f32_vec(s.filter_elems());

    let mut table = Report::new(
        "host conv algorithms 2x32x32x16->32 across the tuner grid \
         (best of 3)",
        &["algorithm", "config", "isa", "ms", "effective GF/s"],
    );
    let mut default_gf = 0.0f64;
    let mut best: Option<(String, f64)> = None;
    for cand in conv_native_grid(true, &[1, 2, 0], &Isa::detect()) {
        let stats = bench(&cand.name(), 1, 3, || {
            black_box(conv2d_native_isa(
                &x,
                &f,
                &s,
                &cand.config,
                &cand.blocked,
                cand.isa,
            ));
        });
        let gf = stats.gflops(flops);
        if cand.config.algorithm == ConvAlgorithm::Im2col
            && cand.blocked == BlockedParams::default()
            && cand.isa == Isa::Scalar
        {
            default_gf = gf;
        }
        if best.as_ref().map(|(_, g)| gf > *g).unwrap_or(true) {
            best = Some((cand.name(), gf));
        }
        table.row(vec![
            cand.config.algorithm.to_string(),
            cand.name(),
            cand.isa.to_string(),
            format!("{:.3}", stats.min.as_secs_f64() * 1e3),
            format!("{gf:.2}"),
        ]);
    }
    println!("\n{}", table.render());
    if let Some((name, gf)) = best {
        println!(
            "algorithm winner: {name} at {gf:.2} GF/s \
             (default im2col: {default_gf:.2} GF/s)"
        );
    }
    table
        .save_csv(Path::new("reports/conv_algo_host.csv"))
        .expect("write csv");
}

/// Measured host anchor for the *Winograd tile-size* axis: the same
/// 3×3/s1 layer through `wino_m ∈ {2, 4}` crossed with every detected
/// micro-kernel ISA, direct calls into `conv2d_winograd` so the row is
/// exactly one transform-domain batched-GEMM lowering.  F(4×4) does
/// 36 transform-domain multiplies where F(2×2) does 16 but replaces
/// 4× as many direct-conv MACs per tile, so the effective-GF/s column
/// shows which tile size the arithmetic saving actually pays on.
fn host_wino() {
    let s = Conv2dShape::same(2, 32, 32, 16, 32, 3, 1);
    let flops = 2 * (s.batch * s.out_h * s.out_w * s.out_c
        * s.window * s.window * s.in_c) as u64;
    let mut rng = XorShift::new(17);
    let x = rng.f32_vec(s.input_elems());
    let f = rng.f32_vec(s.filter_elems());

    let mut table = Report::new(
        "host winograd tile size x isa 2x32x32x16->32 (best of 3)",
        &["wino_m", "isa", "threads", "ms", "effective GF/s"],
    );
    let params = BlockedParams::default();
    for wino_m in [2usize, 4] {
        for &isa in &Isa::detect() {
            for threads in [1usize, 0] {
                let p = BlockedParams { threads, ..params };
                let label = format!("wino{wino_m}_{isa}_t{threads}");
                let stats = bench(&label, 1, 3, || {
                    black_box(conv2d_winograd(&x, &f, &s, wino_m, &p, isa));
                });
                table.row(vec![
                    wino_m.to_string(),
                    isa.to_string(),
                    threads.to_string(),
                    format!("{:.3}", stats.min.as_secs_f64() * 1e3),
                    format!("{:.2}", stats.gflops(flops)),
                ]);
            }
        }
    }
    println!("\n{}", table.render());
    table
        .save_csv(Path::new("reports/conv_wino_host.csv"))
        .expect("write csv");
}

fn main() {
    modeled();
    host_blocked();
    host_algorithms();
    host_wino();
    measured();
}
