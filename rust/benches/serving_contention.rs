//! Bench: serving throughput/latency under contention — client count ×
//! pool size × threads-per-engine, over a synthetic manifest zoo.
//!
//! This is the measurement the serving scale-out exists for: the
//! `threads` kernel knob (intra-engine parallelism) and the pool width
//! (inter-request parallelism) compete for the same cores, and the right
//! split depends on concurrency.  At 1 client a wide-threads single
//! engine wins; at 8 clients, narrow engines behind a pool usually do.
//! Saturation behaviour — not peak — is what separates portable serving
//! configurations (cf. Reguly, arXiv:2309.10075).
//!
//! Run: `cargo bench --bench serving_contention`.
//! Writes `reports/serving_contention.csv`.

use std::path::Path;
use std::time::{Duration, Instant};

use portable_kernels::blas::BlockedParams;
use portable_kernels::coordinator::{EngineClient, EnginePool, PoolConfig};
use portable_kernels::runtime::{ArtifactStore, NativeEngine};
use portable_kernels::util::rng::XorShift;
use portable_kernels::util::tmp::TempDir;

/// Total requests per sweep cell (split across the cell's clients).
const REQUESTS_PER_CELL: usize = 96;
const QUEUE_DEPTH: usize = 64;

fn gemm_entry(name: &str, m: usize) -> String {
    let flops = 2 * (m as u64).pow(3);
    format!(
        r#"{{"name": "{name}", "kind": "gemm", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops},
            "m": {m}, "n": {m}, "k": {m}, "groups": ["gemm"],
            "inputs": [{{"shape": [{m}, {m}], "dtype": "float32"}},
                       {{"shape": [{m}, {m}], "dtype": "float32"}}]}}"#
    )
}

fn conv_entry(name: &str, batch: usize, h: usize, c: usize, k: usize) -> String {
    let flops = 2 * (batch * h * h * k * 9 * c) as u64;
    format!(
        r#"{{"name": "{name}", "kind": "conv", "impl": "native",
            "file": "{name}.hlo.txt", "flops": {flops}, "batch": {batch},
            "algorithm": "im2col", "groups": ["conv"],
            "layer": {{"name": "{name}", "window": 3, "stride": 1,
                       "in_h": {h}, "in_w": {h}, "in_c": {c}, "out_c": {k},
                       "out_h": {h}, "out_w": {h}, "padding": "SAME",
                       "flops": {flops}}},
            "inputs": [{{"shape": [{batch}, {h}, {h}, {c}], "dtype": "float32"}},
                       {{"shape": [3, 3, {c}, {k}], "dtype": "float32"}}]}}"#
    )
}

fn write_zoo(dir: &Path) {
    let entries = [
        gemm_entry("serve_gemm_96", 96),
        gemm_entry("serve_gemm_128", 128),
        gemm_entry("serve_gemm_160", 160),
        gemm_entry("serve_gemm_192", 192),
        conv_entry("serve_conv_16", 2, 16, 8, 16),
        conv_entry("serve_conv_24", 2, 24, 8, 16),
    ];
    std::fs::write(
        dir.join("manifest.json"),
        format!(
            r#"{{"version": 1, "artifacts": [{}]}}"#,
            entries.join(",\n")
        ),
    )
    .unwrap();
}

fn percentile_ms(sorted: &[Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx].as_secs_f64() * 1e3
}

struct Cell {
    clients: usize,
    pool: usize,
    threads: usize,
    rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    wall_s: f64,
    /// Requests placed off their ring-home actor — how often affinity
    /// lost to backpressure in this cell.
    spills: usize,
    /// Kernel-scratch arena checkouts served from pooled buffers during
    /// the measured workload (summed across pool actors).
    scratch_hits: u64,
    /// Arena growth allocations during the measured workload — 0 after
    /// warmup is the zero-allocation steady-state invariant.
    steady_grows: u64,
    /// Arena high-water mark in bytes, summed across pool actors.
    scratch_high_water: u64,
}

fn run_cell(
    store: &ArtifactStore,
    clients: usize,
    pool_size: usize,
    threads: usize,
) -> Cell {
    let config = PoolConfig {
        actors: pool_size,
        queue_depth: QUEUE_DEPTH,
        spill_depth: (QUEUE_DEPTH / 2).max(1),
        ..Default::default()
    };
    let actor_store = store.clone();
    let params = BlockedParams { threads, ..BlockedParams::default() };
    let pool = EnginePool::spawn_with(config, move |_| {
        Ok(NativeEngine::with_params(actor_store.clone(), params))
    })
    .unwrap();

    let names: Vec<String> = store.iter().map(|m| m.name.clone()).collect();
    let mut inputs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(names.len());
    for name in &names {
        inputs.push(pool.synth_inputs(name, 17).unwrap());
        pool.warm(name).unwrap();
    }
    // Arena baseline after warmup: growth past this point means a
    // kernel hot path allocated during steady-state serving.
    let warmed = pool.stats().scratch;

    let per_client = (REQUESTS_PER_CELL / clients).max(1);
    let t0 = Instant::now();
    let mut latencies: Vec<Duration> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let pool = &pool;
                let names = &names;
                let inputs = &inputs;
                s.spawn(move || {
                    let mut rng = XorShift::new(0xbe9c4 + c as u64);
                    let mut lat = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let i =
                            (rng.next_u64() % names.len() as u64) as usize;
                        let t = Instant::now();
                        pool.run(&names[i], inputs[i].clone()).unwrap();
                        lat.push(t.elapsed());
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            latencies.extend(h.join().expect("client thread panicked"));
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let spills = pool.spilled();
    let scratch = pool.stats().scratch;
    pool.shutdown();

    latencies.sort();
    Cell {
        clients,
        pool: pool_size,
        threads,
        rps: (clients * per_client) as f64 / wall,
        p50_ms: percentile_ms(&latencies, 0.50),
        p95_ms: percentile_ms(&latencies, 0.95),
        wall_s: wall,
        spills,
        scratch_hits: scratch.hits.saturating_sub(warmed.hits),
        steady_grows: scratch.grows.saturating_sub(warmed.grows),
        scratch_high_water: scratch.high_water_bytes,
    }
}

fn main() {
    let zoo = TempDir::new("serving-contention").unwrap();
    write_zoo(zoo.path());
    let store = ArtifactStore::open(zoo.path()).unwrap();

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "== serving contention sweep ({} artifacts, {REQUESTS_PER_CELL} \
         requests/cell, {cores} cores) ==",
        store.len()
    );
    println!(
        "{:>7} {:>5} {:>8} | {:>10} {:>9} {:>9} {:>7} {:>6}",
        "clients", "pool", "threads", "req/s", "p50 ms", "p95 ms", "spills",
        "grows"
    );

    let mut csv = String::from(
        "clients,pool,threads,requests,wall_s,throughput_rps,p50_ms,p95_ms,\
         spills,scratch_hits,steady_grows,scratch_high_water_bytes\n",
    );
    for clients in [1usize, 2, 4, 8] {
        for pool_size in [1usize, 2, 4] {
            // threads=0 means "all cores" — each actor's kernels fan out
            // over the whole machine, fighting the pool for cores.
            for threads in [1usize, 2, 0] {
                let cell = run_cell(&store, clients, pool_size, threads);
                println!(
                    "{:>7} {:>5} {:>8} | {:>10.1} {:>9.2} {:>9.2} {:>7} \
                     {:>6}",
                    cell.clients,
                    cell.pool,
                    cell.threads,
                    cell.rps,
                    cell.p50_ms,
                    cell.p95_ms,
                    cell.spills,
                    cell.steady_grows
                );
                csv.push_str(&format!(
                    "{},{},{},{},{:.6},{:.2},{:.4},{:.4},{},{},{},{}\n",
                    cell.clients,
                    cell.pool,
                    cell.threads,
                    REQUESTS_PER_CELL,
                    cell.wall_s,
                    cell.rps,
                    cell.p50_ms,
                    cell.p95_ms,
                    cell.spills,
                    cell.scratch_hits,
                    cell.steady_grows,
                    cell.scratch_high_water
                ));
            }
        }
    }

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/serving_contention.csv", csv).unwrap();
    println!("wrote reports/serving_contention.csv");
}
